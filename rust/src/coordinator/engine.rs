//! `ServeEngine` — the decode/prefill machinery.
//!
//! Each step runs real numerics through the model stages (the pluggable
//! numerics backend — reference or PJRT, DESIGN.md §4) while advancing
//! virtual time against the simulated testbed:
//!
//! ```text
//!   embed ─► for each layer:                         (GPU resource)
//!              attn ─► router ─► policy.plan()
//!              per exec:   [link: weights(+comp) if cache miss] ─► GPU FFN
//!                       or [ndp-link: acts] ─► NDP FFN ─► [acts back]
//!              combine (host) ─► barrier
//!          ─► head ─► sample
//! ```
//!
//! Transfers and compute acquire different virtual resources, so expert
//! *i*'s compute overlaps expert *i+1*'s transfer exactly as the real
//! pipelined fetch does.  All byte counts come from the manifest's
//! transfer tables (true packed sizes — DESIGN.md §7).
//!
//! Under expert-parallel sharding (`ShardConfig::devices > 1`, DESIGN.md
//! §11) the engine drives a *fleet*: every device owns a compute stream,
//! a host link and an `ExpertCache`; experts are statically owned
//! round-robin, token batches for remote experts pay activation round
//! trips on the dev↔dev peer links, and a popularity-driven replicator
//! pins hot remote experts into per-device replica regions at decode-step
//! boundaries.  `D = 1` materializes exactly one device on the old wiring
//! and is pinned byte-identical to the pre-sharding engine.
//!
//! A scripted [`FaultPlan`] (DESIGN.md §12) makes the fleet *mortal*:
//! at each decode-step boundary due events are applied — device loss
//! (HBM purged, queued work and links aborted, orphaned owner experts
//! re-owned hottest-first, in-flight transfers from the dead source
//! requeued as demand fetches), hot-add (experts return to their static
//! home; replicas refill via the popularity reconcile, not a re-shard),
//! link degradation and transient stalls.  Routing then simply never
//! selects a dead device, so tokens keep flowing off surviving copies;
//! the recovery ledger lands in [`Report::fault`].  Token numerics are
//! placement-independent by construction, so faults can only move
//! *time*, never values — the chaos goldens pin both.  With no plan (or
//! an empty one) none of this wiring runs and the ledger is
//! byte-identical to the §11 engine.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::backend::Tensor;
use crate::config::{ModelDims, PolicyConfig, Precision, PrefetchConfig, SystemConfig};
use crate::coordinator::combine;
use crate::coordinator::metrics::{
    ElasticReport, FaultReport, PrefetchReport, Report, RequestRecord, ShardReport, StepBreakdown,
};
use crate::coordinator::state::{ActiveSeq, BatchState, LayerKv};
use crate::offload::cache::{ExpertCache, PayloadKey, PayloadKind};
use crate::offload::ndp::NdpDevice;
use crate::offload::prefetch::PrefetchQueue;
use crate::offload::replicate::{plan_reowning, Replicator};
use crate::offload::transfer::{Link, TransferClass, TransferLog};
use crate::policies::make_policy;
use crate::policies::plan::{LayerPlacement, LayerPlan, Location, PlanCtx, Policy};
use crate::predict::{make_predictor, EwmaPopularity, ExpertPredictor, LayerObservation, PredictCtx};
use crate::quant::alloc::{ElasticAction, PrecisionAllocator};
use crate::runtime::StagedModel;
use crate::sim::clock::{Resource, VTime, VirtualClock};
use crate::sim::topology::{FaultEvent, FaultKind, FaultPlan, LinkSpec, Topology};
use crate::sim::CostModel;
use crate::workload::{DecodeTrace, Request};

/// `Copy` snapshot of the manifest dims the hot paths read every step.
/// The serve loop used to `manifest.model.clone()` (heap `name` clone
/// included) once per decode step, prefill pass, MoE layer and prefetch
/// issue just to end the borrow of `self.model`; a scalar snapshot makes
/// that free.
#[derive(Clone, Copy)]
struct HotDims {
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
    n_shared: usize,
    t_prefill: usize,
    b_max: usize,
    d_model: usize,
    vocab: usize,
}

impl HotDims {
    fn of(m: &ModelDims) -> Self {
        HotDims {
            n_layers: m.n_layers,
            n_experts: m.n_experts,
            top_k: m.top_k,
            n_shared: m.n_shared,
            t_prefill: m.t_prefill,
            b_max: m.b_max,
            d_model: m.d_model,
            vocab: m.vocab,
        }
    }
}

/// One expert-parallel device: compute stream, host link, payload cache
/// (DESIGN.md §11).  Device 0 additionally runs the dense stages (embed,
/// attention, router, head, shared experts).
struct DeviceState {
    gpu: Resource,
    host_link: Link,
    cache: ExpertCache,
    /// Decode-time demand fetches issued on this device's host link.
    demand_fetches: u64,
    /// Expert execs run on this device.
    execs: u64,
}

/// Runtime state of the fault-injection subsystem (DESIGN.md §12);
/// constructed only for a non-empty [`FaultPlan`], so its absence is the
/// byte-identical no-fault path.
struct FaultState {
    /// Scripted events not yet applied (script order preserved).
    pending: Vec<FaultEvent>,
    /// Per-device liveness; routing never selects a dead device.
    alive: Vec<bool>,
    /// The topology's base host-link specs — what `LinkRestore` restores.
    base_host: Vec<LinkSpec>,
    /// Re-owning overlay: `reowned[e] = Some(d)` moves expert `e`'s
    /// ownership to survivor `d`; `None` defers to `Topology::owner_of`.
    reowned: Vec<Option<usize>>,
    /// Own popularity table for hottest-first re-owning — the replicator's
    /// is absent on budget-0 fleets, and orphans must re-own there too.
    ewma: EwmaPopularity,
    report: FaultReport,
}

/// One generated token tagged for the session layer (`server::Server`
/// drains these after every step and routes them into `TokenEvent`
/// streams).
#[derive(Debug, Clone, Copy)]
pub(crate) struct EmittedToken {
    pub request_id: u64,
    pub token: i32,
    /// 0-based index among the request's generated tokens.
    pub index: usize,
    /// Virtual time the step that produced the token completed.
    pub at: VTime,
    /// This token completes the request.
    pub last: bool,
}

/// Read-only snapshot of engine progress (the façade's replacement for
/// the `pub` fields `ServeEngine` no longer exposes).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub virtual_now: VTime,
    pub decode_steps: u64,
    pub prefills: u64,
    pub total_generated: usize,
    /// Batch slots currently bound to live sequences.
    pub active_slots: usize,
    /// Requests that ran to completion (cancelled ones excluded).
    pub completed_requests: usize,
}

/// Read-only view of the expert cache's economics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheView {
    pub entries: usize,
    pub used_bytes: usize,
    pub capacity_bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub hit_rate: f64,
}

pub struct ServeEngine {
    model: StagedModel,
    /// Scalar copy of `model.manifest.model` for the per-step paths.
    dims: HotDims,
    policy_cfg: PolicyConfig,
    policy: Box<dyn Policy>,
    cost: CostModel,
    /// The expert-parallel fleet; `devices[0]` is the wiring every
    /// pre-sharding run used (`D = 1` ⇒ exactly that, nothing else).
    devices: Vec<DeviceState>,
    /// Directed dev↔dev peer links, `peer[src][dst]` (`None` diagonal).
    peer: Vec<Vec<Option<Link>>>,
    topology: Topology,
    /// Popularity-driven hot-expert replication (DESIGN.md §11); present
    /// only when `D > 1` and the replica budget is nonzero.
    replicator: Option<Replicator>,
    /// Fault-injection state (DESIGN.md §12); present only when a
    /// non-empty `FaultPlan` was installed.
    faults: Option<FaultState>,
    /// Execs dispatched off device 0 (paid an activation round trip).
    remote_execs: u64,
    /// Execs served by a landed copy on a non-owner device.
    replica_serves: u64,
    ndp: Option<NdpDevice>,
    ndp_link: Option<Link>,
    pub(crate) clock: VirtualClock,
    pub(crate) state: BatchState,
    breakdown: StepBreakdown,
    /// [layer][expert] mean true compensator rank (cost model input).
    avg_ranks: Vec<Vec<f64>>,
    trace: Option<DecodeTrace>,
    /// Prefetch knobs (DESIGN.md §8); `PrefetchConfig::off()` reproduces
    /// the demand-only loop byte-for-byte.
    prefetch_cfg: PrefetchConfig,
    predictor: Option<Box<dyn ExpertPredictor>>,
    /// Speculative-transfer budget/coverage bookkeeping.
    prefetch: PrefetchQueue,
    /// layer → dense predictor scores, refreshed as predictions are made
    /// (surfaced to policies through `PlanCtx::predicted`).
    predicted_scores: HashMap<usize, Vec<f64>>,
    /// Budgeted per-expert precision allocator (DESIGN.md §10) — present
    /// only when the policy consumes its plan (`wants_precision_plan`).
    /// Re-plans at decode-step boundaries; its per-layer map reaches the
    /// policy through `PlanCtx::precisions`.
    alloc: Option<PrecisionAllocator>,
    /// Boundary promotions issued under the requant budget (elastic
    /// residency, DESIGN.md §15); all zero when the budget is zero.
    elastic_promotions: u64,
    elastic_promoted_bytes: usize,
    /// Decode-time demand fetches that paid only a delta over a landed
    /// lower-rung resident copy instead of the full payload.
    elastic_demand_promotions: u64,
    /// The MoE layer currently executing belongs to a prefill step
    /// (prefetch stats track the decode critical path only).
    in_prefill: bool,
    decode_steps: u64,
    prefills: u64,
    total_generated: usize,
    records: Vec<RequestRecord>,
    /// Tokens generated since the session layer last drained.
    emitted: Vec<EmittedToken>,
    started: Instant,
    // -- scratch buffers (perf): reused across decode-step boundaries so
    // the hot loop stops reallocating them every step.  Each is taken
    // (`mem::take`), cleared/refilled, and put back — never observed
    // between uses, so they carry no state across steps.
    /// MoE accumulator `run_moe_layer` fills per layer.
    scratch_moe: Vec<f32>,
    /// Per-device desired replica sets (the §11 reconcile diff).
    scratch_desired: Vec<HashSet<(PayloadKey, PayloadKind)>>,
    /// Pinned-key listing for the reconcile's stale-replica sweep.
    scratch_pinned: Vec<(PayloadKey, PayloadKind)>,
    /// `[layer][expert]` resident-rung table the elastic step diffs.
    scratch_resident: Vec<Vec<Option<Precision>>>,
}

impl ServeEngine {
    /// Demand-only engine (no speculation) — the seed behaviour.
    pub fn new(model: StagedModel, policy_cfg: PolicyConfig, sys: SystemConfig) -> Result<Self> {
        Self::with_prefetch(model, policy_cfg, sys, PrefetchConfig::off())
    }

    /// Engine with a speculative prefetch subsystem (DESIGN.md §8).
    pub fn with_prefetch(
        model: StagedModel,
        policy_cfg: PolicyConfig,
        sys: SystemConfig,
        prefetch_cfg: PrefetchConfig,
    ) -> Result<Self> {
        Self::with_config(model, policy_cfg, sys, prefetch_cfg, None)
    }

    /// Full constructor: prefetching plus an optional scripted
    /// [`FaultPlan`] (DESIGN.md §12).  `None` — or an empty plan — builds
    /// no fault state at all and stays byte-identical to
    /// [`ServeEngine::with_prefetch`].
    pub fn with_config(
        model: StagedModel,
        policy_cfg: PolicyConfig,
        sys: SystemConfig,
        prefetch_cfg: PrefetchConfig,
        fault_plan: Option<FaultPlan>,
    ) -> Result<Self> {
        let dims = model.manifest.model.clone();
        let cost = CostModel::new(sys.clone(), dims.clone());
        let state = BatchState::new(&model)?;
        let avg_ranks = Self::rank_table(&model, &policy_cfg.comp_tag)?;
        let ndp = sys.ndp.as_ref().map(|n| NdpDevice::new(n.clone()));
        let ndp_link = sys
            .ndp
            .as_ref()
            .map(|n| Link::new("ndp-link", n.link_bw, n.link_lat));
        let topology = Topology::from_system(&sys);
        let devices: Vec<DeviceState> = topology
            .host
            .iter()
            .map(|spec| DeviceState {
                gpu: Resource::new("gpu"),
                host_link: Link::new("pcie", spec.bw, spec.lat),
                cache: ExpertCache::new(sys.gpu_cache_bytes),
                demand_fetches: 0,
                execs: 0,
            })
            .collect();
        let peer: Vec<Vec<Option<Link>>> = topology
            .peer
            .iter()
            .map(|row| {
                row.iter()
                    .map(|spec| spec.map(|s| Link::new("peer", s.bw, s.lat)))
                    .collect()
            })
            .collect();
        let replicator = (topology.n_devices > 1 && sys.shard.replicate_budget_bytes > 0)
            .then(|| {
                Replicator::new(
                    dims.n_layers,
                    dims.n_experts,
                    topology.n_devices,
                    sys.shard.replicate_budget_bytes,
                )
            });
        let faults = match fault_plan {
            Some(plan) if !plan.is_empty() => {
                plan.validate(topology.n_devices)?;
                Some(FaultState {
                    pending: plan.events,
                    alive: vec![true; topology.n_devices],
                    base_host: topology.host.clone(),
                    reowned: vec![None; dims.n_experts],
                    // Same smoothing constant as §10/§11: popularity is one
                    // signal, consumed by three planners.
                    ewma: EwmaPopularity::new(dims.n_layers, dims.n_experts, 0.25),
                    report: FaultReport::default(),
                })
            }
            _ => None,
        };
        let predictor = make_predictor(&prefetch_cfg.predictor, dims.n_layers, dims.n_experts)?;
        let policy = make_policy(&policy_cfg)?;
        let alloc = if policy.wants_precision_plan() {
            // `cfg.bits` is the adaptive floor: the ladder never serves an
            // expert below it (and fails fast if the artifact cannot).
            Some(PrecisionAllocator::new(
                &model.manifest,
                &policy_cfg.comp_tag,
                policy_cfg.bits,
                policy_cfg.alloc_budget_bytes,
            )?)
        } else {
            None
        };
        let mut engine = ServeEngine {
            dims: HotDims::of(&dims),
            policy,
            policy_cfg,
            cost,
            devices,
            peer,
            topology,
            replicator,
            faults,
            remote_execs: 0,
            replica_serves: 0,
            ndp,
            ndp_link,
            clock: VirtualClock::new(),
            state,
            breakdown: StepBreakdown::default(),
            avg_ranks,
            trace: None,
            prefetch: PrefetchQueue::new(prefetch_cfg.budget_bytes),
            prefetch_cfg,
            predictor,
            predicted_scores: HashMap::new(),
            alloc,
            elastic_promotions: 0,
            elastic_promoted_bytes: 0,
            elastic_demand_promotions: 0,
            in_prefill: false,
            decode_steps: 0,
            prefills: 0,
            total_generated: 0,
            records: Vec::new(),
            emitted: Vec::new(),
            started: Instant::now(),
            scratch_moe: Vec::new(),
            scratch_desired: Vec::new(),
            scratch_pinned: Vec::new(),
            scratch_resident: Vec::new(),
            model,
        };
        if engine.elastic_active() {
            for d in engine.devices.iter_mut() {
                d.cache.set_elastic(true);
            }
        }
        engine.prewarm()?;
        Ok(engine)
    }

    // -- read-only façade (DESIGN.md §9): the fields behind these used to
    // be `pub`; binaries/examples/figures now go through `server::Server`,
    // which forwards here -------------------------------------------------

    /// The staged model this engine serves (manifest, stages, store).
    pub fn model(&self) -> &StagedModel {
        &self.model
    }

    /// The policy knob set the engine was built with.
    pub fn policy_config(&self) -> &PolicyConfig {
        &self.policy_cfg
    }

    /// The prefetch knob set the engine was built with.
    pub fn prefetch_config(&self) -> &PrefetchConfig {
        &self.prefetch_cfg
    }

    /// Snapshot of serve-loop progress.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            virtual_now: self.clock.now(),
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            total_generated: self.total_generated,
            active_slots: self.state.n_active(),
            completed_requests: self.records.len(),
        }
    }

    /// Snapshot of the expert caches' economics, aggregated over the
    /// fleet (a single device's numbers when `D = 1`).
    pub fn cache_view(&self) -> CacheView {
        let (mut hits, mut misses) = (0u64, 0u64);
        let mut view = CacheView::default();
        // Per device: LRU capacity plus the reserved replica region (the
        // replicate budget), so `used <= capacity` holds fleet-wide.
        let replica_cap = if self.replicator.is_some() {
            self.cost.sys.shard.replicate_budget_bytes
        } else {
            0
        };
        for d in &self.devices {
            view.entries += d.cache.len();
            view.used_bytes += d.cache.used_bytes() + d.cache.pinned_bytes();
            view.capacity_bytes += d.cache.capacity() + replica_cap;
            hits += d.cache.hits;
            misses += d.cache.misses;
            view.evictions += d.cache.evictions;
        }
        view.hits = hits;
        view.misses = misses;
        view.hit_rate = if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        };
        view
    }

    /// Aggregate cache hit rate across the fleet (the `Report` field).
    fn fleet_hit_rate(&self) -> f64 {
        self.cache_view().hit_rate
    }

    /// Record decode routing from now on (the Fig. 2 trace and the
    /// oracle-replay recording pass).
    pub fn record_trace(&mut self) {
        self.trace = Some(DecodeTrace::default());
    }

    /// Take the recorded decode trace; contextful error when tracing was
    /// never enabled (the old `trace.take().unwrap()` panic path).
    pub fn take_trace(&mut self) -> Result<DecodeTrace> {
        self.trace
            .take()
            .context("no decode trace recorded — call record_trace() before serving")
    }

    /// Install the recorded trace a trace-replaying predictor (e.g.
    /// `oracle`) replays; no-op for predictors that learn online.
    pub fn set_oracle_trace(&mut self, trace: &DecodeTrace) {
        if let Some(p) = self.predictor.as_mut() {
            p.install_trace(trace);
        }
    }

    /// Does the configured predictor need a recorded trace installed
    /// before serving ([`ServeEngine::set_oracle_trace`])?
    pub fn needs_recorded_trace(&self) -> bool {
        self.predictor.as_ref().is_some_and(|p| p.wants_trace())
    }

    /// Can this run ever issue a speculative transfer?  Ground truth for
    /// "is prefetching on": a predictor was actually constructed (the
    /// registry's call — an off-like name builds `None`) *and* the
    /// numeric knobs permit issuing.
    pub fn speculation_active(&self) -> bool {
        self.predictor.is_some() && self.prefetch_cfg.issuable()
    }

    // -- live-reconfiguration seams (DESIGN.md §14): each setter changes
    // exactly one knob and is only invoked between ticks — the same
    // boundary the §10 replan / §11 reconcile / §12 fault-apply run at,
    // so determinism and the per-step ledgers are preserved ----------------

    /// A predictor was constructed, so the prefetch knobs are live (an
    /// off-like predictor name builds none — retuning budgets then would
    /// be a silent no-op, which the control plane rejects instead).
    pub fn has_predictor(&self) -> bool {
        self.predictor.is_some()
    }

    /// Current per-decode-step speculative transfer budget (bytes).
    pub fn prefetch_budget(&self) -> usize {
        self.prefetch_cfg.budget_bytes
    }

    /// Retarget the speculative budget.  Effective at the next decode
    /// step: prefetches are only issued inside `decode_step`, and the
    /// issue path re-reads both the config and the queue budget fresh.
    pub fn set_prefetch_budget(&mut self, bytes: usize) {
        self.prefetch_cfg.budget_bytes = bytes;
        self.prefetch.step_budget = bytes;
    }

    /// Current prefetch lookahead (layers ahead predictions target).
    pub fn prefetch_lookahead(&self) -> usize {
        self.prefetch_cfg.lookahead
    }

    /// Retarget the lookahead (read fresh at every issue).
    pub fn set_prefetch_lookahead(&mut self, lookahead: usize) {
        self.prefetch_cfg.lookahead = lookahead;
    }

    /// The §10 precision allocator's byte budget; `None` when the policy
    /// consumes no precision plan (no allocator was built).
    pub fn alloc_budget(&self) -> Option<usize> {
        self.alloc.as_ref().map(PrecisionAllocator::budget)
    }

    /// Retarget the allocator budget; the §10 replan at the next decode
    /// boundary re-plans under it.  `false` when no allocator exists.
    pub fn set_alloc_budget(&mut self, bytes: usize) -> bool {
        match self.alloc.as_mut() {
            Some(a) => {
                a.set_budget(bytes);
                true
            }
            None => false,
        }
    }

    /// The elastic-residency requant budget (DESIGN.md §15): promotion
    /// delta bytes allowed per replan boundary.  `None` when the policy
    /// consumes no precision plan — without an allocator there is no
    /// target rung to promote toward, so the knob is meaningless.
    pub fn requant_budget(&self) -> Option<usize> {
        self.alloc.as_ref().map(|_| self.policy_cfg.requant_budget_bytes)
    }

    /// Retarget the requant budget; the elastic pass at the next decode
    /// boundary runs under it.  `0 → nonzero` arms the elastic machinery
    /// live (demote-first eviction included); `nonzero → 0` disarms it,
    /// returning the serve to the plain demand/evict path.  `false` when
    /// no allocator exists.
    pub fn set_requant_budget(&mut self, bytes: usize) -> bool {
        if self.alloc.is_none() {
            return false;
        }
        self.policy_cfg.requant_budget_bytes = bytes;
        let on = bytes > 0;
        for d in self.devices.iter_mut() {
            d.cache.set_elastic(on);
        }
        true
    }

    /// Is the elastic-residency machinery live?  Requires both a precision
    /// allocator (the target rungs) and a nonzero requant budget; at zero
    /// budget none of the elastic wiring runs and the serve is
    /// byte-identical to the pre-elastic engine.
    fn elastic_active(&self) -> bool {
        self.alloc.is_some() && self.policy_cfg.requant_budget_bytes > 0
    }

    /// The live per-device replica budget: what the replicator actually
    /// plans under, `0` when replication is inactive.
    pub fn replicate_budget(&self) -> usize {
        self.replicator.as_ref().map_or(0, Replicator::budget_bytes)
    }

    /// Number of devices in the expert-parallel fleet.
    pub fn n_devices(&self) -> usize {
        self.topology.n_devices
    }

    /// Retarget the per-device replica budget (DESIGN.md §11).  The §11
    /// reconcile at the next decode boundary re-plans under it — a shrunk
    /// (or zeroed) budget empties the plan and unpins stale replicas; a
    /// 0→nonzero change on a multi-device fleet constructs a fresh
    /// replicator whose popularity EWMA warms over the following steps.
    /// `false` on a single-device fleet, where replication cannot apply.
    /// The cost model's view of the budget is kept in sync so
    /// `cache_view` capacities and the shard report stay consistent.
    pub fn set_replicate_budget(&mut self, bytes: usize) -> bool {
        if self.topology.n_devices < 2 {
            return false;
        }
        self.cost.sys.shard.replicate_budget_bytes = bytes;
        match self.replicator.as_mut() {
            Some(r) => r.set_budget_bytes(bytes),
            None => {
                if bytes > 0 {
                    let dims = &self.model.manifest.model;
                    self.replicator = Some(Replicator::new(
                        dims.n_layers,
                        dims.n_experts,
                        self.topology.n_devices,
                        bytes,
                    ));
                }
            }
        }
        true
    }

    /// Per-device cache snapshots in fleet order (the `beamctl status`
    /// surface; [`ServeEngine::cache_view`] is their aggregate).
    pub fn device_cache_views(&self) -> Vec<CacheView> {
        let replica_cap = if self.replicator.is_some() {
            self.cost.sys.shard.replicate_budget_bytes
        } else {
            0
        };
        self.devices
            .iter()
            .map(|d| {
                let (hits, misses) = (d.cache.hits, d.cache.misses);
                CacheView {
                    entries: d.cache.len(),
                    used_bytes: d.cache.used_bytes() + d.cache.pinned_bytes(),
                    capacity_bytes: d.cache.capacity() + replica_cap,
                    hits,
                    misses,
                    evictions: d.cache.evictions,
                    hit_rate: if hits + misses == 0 {
                        0.0
                    } else {
                        hits as f64 / (hits + misses) as f64
                    },
                }
            })
            .collect()
    }

    /// Tokens generated since the last drain (session-event seam).
    pub(crate) fn take_emitted(&mut self) -> Vec<EmittedToken> {
        std::mem::take(&mut self.emitted)
    }

    /// Drop undelivered per-token events (the legacy `serve` loop has no
    /// session layer; without this a long run would retain one entry per
    /// generated token).
    pub(crate) fn discard_emitted(&mut self) {
        self.emitted.clear();
    }

    /// Slot currently bound to `request_id`, if any.
    pub(crate) fn slot_of(&self, request_id: u64) -> Option<usize> {
        self.state
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|q| q.request_id == request_id))
    }

    /// Release `slot` without recording a completion (session cancel).
    pub(crate) fn cancel_slot(&mut self, slot: usize) -> Option<ActiveSeq> {
        self.state.release(slot)
    }

    /// Policies may pin FP16 experts in GPU HBM at model-load time (the
    /// MoNDE hot/cold split of Kim et al. 2024); no link charge.
    /// Layer-major order is a stable stand-in for offline hotness ranking;
    /// each expert prewarms into its *owner* device's cache (the single
    /// device when `D = 1`).
    fn prewarm(&mut self) -> Result<()> {
        if !self.policy.prewarm_fp16() {
            return Ok(());
        }
        let dims = self.dims;
        let bytes = self.model.manifest.transfer.fp16_expert_bytes;
        for layer in 0..dims.n_layers {
            for expert in 0..dims.n_experts {
                let dev = self.topology.owner_of(expert);
                let cache = &self.devices[dev].cache;
                if cache.used_bytes() + bytes > cache.capacity() {
                    continue;
                }
                let key = PayloadKey { layer, expert };
                let lits =
                    Arc::new(self.model.payload_base(layer, expert, Precision::Fp16, "hqq")?);
                self.devices[dev].cache.insert(key, PayloadKind::Fp16, lits, bytes);
            }
        }
        Ok(())
    }

    fn rank_table(model: &StagedModel, tag: &str) -> Result<Vec<Vec<f64>>> {
        let m = &model.manifest.model;
        let mut out = vec![vec![0f64; m.n_experts]; m.n_layers];
        if let Some(entry) = model.manifest.rank_table.get(tag) {
            for (key, rank) in model.manifest.mat_keys.iter().zip(&entry.ranks) {
                let mut it = key.split('.');
                let l: usize = it.next().context("mat key")?.parse()?;
                let e: usize = it.next().context("mat key")?.parse()?;
                out[l][e] += *rank as f64 / 3.0;
            }
        }
        Ok(out)
    }

    /// Quantizer family for payloads: GPTQ only when explicitly selected
    /// via the comp-free accuracy baselines; BEAM ships HQQ (paper §3.1).
    fn method(&self) -> &str {
        &self.policy_cfg.method
    }

    fn payload_kind(precision: Precision) -> PayloadKind {
        match precision {
            Precision::Fp16 => PayloadKind::Fp16,
            Precision::Int(b) | Precision::IntComp(b) => PayloadKind::Quant(b),
        }
    }

    /// Wire bytes of an expert's base payload at `precision`.
    fn base_bytes(&self, precision: Precision) -> usize {
        match precision {
            Precision::Fp16 => self.model.manifest.transfer.fp16_expert_bytes,
            Precision::Int(b) | Precision::IntComp(b) => self.model.manifest.q_expert_bytes(b),
        }
    }

    /// Fetch (or hit) the base payload on device `dev`; returns (tensors,
    /// ready time).  A cache entry whose transfer is still in flight (a
    /// prefetch, a replica copy, or a demand fetch another exec issued) is
    /// *joined*: no second transfer, but the requester inherits the
    /// in-flight completion time.  Misses fetch over `dev`'s host link —
    /// except under elastic residency (DESIGN.md §15), where a landed
    /// sibling level of the same expert shortcuts the wire: a *lower*
    /// resident rung pays only the delta bytes (demand promotion) and a
    /// *higher* one requantizes in place for free (demote-serve).
    fn acquire_base(
        &mut self,
        dev: usize,
        layer: usize,
        expert: usize,
        precision: Precision,
        ready: VTime,
    ) -> Result<(Arc<Vec<Tensor>>, VTime)> {
        let key = PayloadKey { layer, expert };
        let kind = Self::payload_kind(precision);
        if let Some(hit) = self.devices[dev].cache.get_at(&key, kind, ready) {
            // First use of a speculative entry consumes its one-shot flag,
            // so credit coverage regardless of prefill/decode — the
            // prefetch saved a real link fetch either way.
            if hit.first_spec_use {
                self.prefetch.covered += 1;
            }
            return Ok((hit.payload, ready.max(hit.ready_at)));
        }
        let lits = Arc::new(self.model.payload_base(layer, expert, precision, self.method())?);
        let bytes = self.base_bytes(precision);
        let (wire_bytes, demand_promo) = if self.elastic_active() {
            // Largest landed base level of this expert (compensators can't
            // seed a base); in-flight levels can't be reused — their data
            // isn't on-device yet.
            let best = self.devices[dev]
                .cache
                .level_info(&key)
                .into_iter()
                .filter(|&(k, _, r)| !matches!(k, PayloadKind::Comp(_)) && r <= ready)
                .map(|(_, b, _)| b)
                .max();
            match best {
                Some(b) if b >= bytes => (0, false), // requantize down in place
                Some(b) => (bytes - b, true),        // pay only the delta up
                None => (bytes, false),
            }
        } else {
            (bytes, false)
        };
        let done =
            self.devices[dev].host_link.transfer(ready, wire_bytes, TransferClass::ExpertWeights);
        // A zero-wire serve (requantize-on-device) never hit the link, so
        // it is not a demand fetch; with elastic off `wire_bytes == bytes
        // > 0` always, so this is exactly the legacy counting.
        if !self.in_prefill && wire_bytes > 0 {
            self.prefetch.demand_fetches += 1;
            self.devices[dev].demand_fetches += 1;
        }
        if demand_promo {
            self.elastic_demand_promotions += 1;
        }
        self.devices[dev].cache.insert_ready(key, kind, Arc::clone(&lits), bytes, done);
        // Allocator-driven serves supersede stale sibling precisions of
        // the same expert (the replan-leaves-dead-bytes fix); policies
        // without a precision plan may hold several precisions at once
        // legitimately (HOBBIT's hi/lo pair) and are left alone.
        if self.alloc.is_some() {
            self.devices[dev].cache.supersede(&key, kind);
        }
        Ok((lits, done))
    }

    /// Fetch (or hit) the compensator payload for `bits` on device `dev`
    /// (never speculated: compensators are tiny and token-dependent).
    fn acquire_comp(
        &mut self,
        dev: usize,
        layer: usize,
        expert: usize,
        bits: u8,
        ready: VTime,
    ) -> Result<(Arc<Vec<Tensor>>, VTime)> {
        let key = PayloadKey { layer, expert };
        let kind = PayloadKind::Comp(bits);
        if let Some(hit) = self.devices[dev].cache.get_at(&key, kind, ready) {
            return Ok((hit.payload, ready.max(hit.ready_at)));
        }
        let tag = &self.policy_cfg.comp_tag;
        let lits = Arc::new(self.model.payload_comp(layer, expert, bits, tag)?);
        let bytes = self.model.manifest.comp_bytes(tag, bits, layer, expert);
        let done =
            self.devices[dev].host_link.transfer(ready, bytes, TransferClass::Compensator);
        self.devices[dev].cache.insert_ready(key, kind, Arc::clone(&lits), bytes, done);
        Ok((lits, done))
    }

    /// Queue a transfer on the directed `src → dst` peer link.
    fn peer_transfer(
        &mut self,
        src: usize,
        dst: usize,
        ready: VTime,
        bytes: usize,
        class: TransferClass,
    ) -> VTime {
        self.peer[src][dst]
            .as_mut()
            .expect("peer link exists for distinct devices")
            .transfer(ready, bytes, class)
    }

    /// Pick the device that serves this exec: the cheapest *landed* copy
    /// (earliest-free compute stream; the owner wins ties, then the lower
    /// index), falling back to the owner — who then demand-fetches over
    /// its host link.  The probe is economics-free (`peek_ready_at`), so
    /// `D = 1` routing (always device 0) perturbs nothing.
    fn choose_device(&self, key: &PayloadKey, kind: PayloadKind, owner: usize, now: VTime) -> usize {
        if self.devices.len() == 1 {
            return 0;
        }
        let mut best: Option<(f64, usize)> = None;
        for (d, dev) in self.devices.iter().enumerate() {
            if !self.device_alive(d) {
                continue;
            }
            if dev.cache.peek_ready_at(key, kind).is_some_and(|t| t <= now) {
                let free = dev.gpu.free_at();
                let better = match best {
                    None => true,
                    Some((bf, bd)) => {
                        free < bf || (free == bf && (d == owner || (bd != owner && d < bd)))
                    }
                };
                if better {
                    best = Some((free, d));
                }
            }
        }
        best.map_or(owner, |(_, d)| d)
    }

    /// Is device `d` currently alive?  Always true without a fault plan —
    /// the probe compiles down to nothing on the no-fault path.
    fn device_alive(&self, d: usize) -> bool {
        self.faults.as_ref().is_none_or(|f| f.alive[d])
    }

    /// `expert`'s current owner: the re-owning overlay when a fault plan
    /// moved it off a dead device, else the topology's static assignment.
    fn effective_owner(&self, expert: usize) -> usize {
        match &self.faults {
            Some(f) => f.reowned[expert].unwrap_or_else(|| self.topology.owner_of(expert)),
            None => self.topology.owner_of(expert),
        }
    }

    /// Apply every due scripted fault at this decode-step boundary
    /// (DESIGN.md §12).  Returns whether a device loss fired — the caller
    /// attributes this step's extra weight stall to the recovery window.
    fn apply_faults(&mut self) -> bool {
        let Some(mut fs) = self.faults.take() else {
            return false;
        };
        let out = self.apply_faults_with(&mut fs);
        self.faults = Some(fs);
        out
    }

    fn apply_faults_with(&mut self, fs: &mut FaultState) -> bool {
        let now = self.clock.now();
        let step = self.decode_steps;
        let mut due: Vec<FaultEvent> = Vec::new();
        fs.pending.retain(|ev| {
            let fire = now >= ev.at && step >= ev.after_step;
            if fire {
                due.push(*ev);
            }
            !fire
        });
        let mut loss = false;
        for ev in due {
            fs.report.events_applied += 1;
            match ev.kind {
                FaultKind::DeviceDown { device } => {
                    if !fs.alive[device] {
                        continue; // scripted kill of an already-dead device
                    }
                    fs.alive[device] = false;
                    fs.report.device_losses += 1;
                    loss = true;
                    // Abort the dead device's queued work: its compute
                    // stream, host link and every peer link touching it
                    // must not gate this step's barrier.
                    self.devices[device].gpu.cut_to(now);
                    self.devices[device].host_link.resource.cut_to(now);
                    for other in 0..self.devices.len() {
                        if other == device {
                            continue;
                        }
                        if let Some(l) = self.peer[device][other].as_mut() {
                            l.resource.cut_to(now);
                        }
                        if let Some(l) = self.peer[other][device].as_mut() {
                            l.resource.cut_to(now);
                        }
                    }
                    // HBM contents are gone (stats survive — the run
                    // continues), and survivors drop any copy still on the
                    // wire *from* the dead device: its advertised landing
                    // time is a lie, so the next access demand-fetches.
                    self.devices[device].cache.purge();
                    for (d, dev) in self.devices.iter_mut().enumerate() {
                        if fs.alive[d] {
                            fs.report.requeued_fetches +=
                                dev.cache.drop_in_flight_from(device, now) as u64;
                        }
                    }
                    // Re-own the orphans hottest-first onto the survivors.
                    let topo = &self.topology;
                    let moves = plan_reowning(
                        fs.ewma.scores(),
                        |e| topo.owner_of(e),
                        &fs.reowned,
                        &fs.alive,
                    );
                    for (expert, home) in moves {
                        fs.reowned[expert] = Some(home);
                        fs.report.reowned_experts += 1;
                    }
                }
                FaultKind::DeviceUp { device } => {
                    if fs.alive[device] {
                        continue; // hot-add of a device that never left
                    }
                    fs.alive[device] = true;
                    fs.report.device_revivals += 1;
                    self.devices[device].gpu.sync_to(now);
                    self.devices[device].host_link.resource.sync_to(now);
                    // Partial rebalance, not a re-shard: the revived
                    // device's *static* experts come home (its HBM refills
                    // on demand / via the replica reconcile); experts
                    // re-owned between other devices stay put.
                    for e in 0..fs.reowned.len() {
                        if self.topology.owner_of(e) == device {
                            fs.reowned[e] = None;
                        }
                    }
                }
                FaultKind::LinkDegrade { device, factor } => {
                    self.devices[device].host_link.bw = fs.base_host[device].bw * factor;
                    fs.report.link_degrades += 1;
                }
                FaultKind::LinkRestore { device } => {
                    self.devices[device].host_link.bw = fs.base_host[device].bw;
                }
                FaultKind::Stall { device, seconds } => {
                    if !fs.alive[device] {
                        continue; // a dead device cannot stall anyone
                    }
                    self.devices[device].gpu.acquire(now, seconds);
                    fs.report.stalls_injected += 1;
                    fs.report.stall_injected_s += seconds;
                }
            }
        }
        loss
    }

    fn plan_layer(&self, probs: &[f32], active: &[bool], layer: usize) -> LayerPlan {
        let m = &self.model.manifest.model;
        let devices = &self.devices;
        let probe = move |e: usize| {
            let key = PayloadKey { layer, expert: e };
            devices.iter().any(|d| d.cache.contains(&key, PayloadKind::Fp16))
        };
        // The placement view exists only on fleets — `D = 1` planning
        // inputs are exactly the pre-sharding ones (the §11 equivalence
        // rule covers the planner too).
        let placement = (devices.len() > 1).then(|| {
            let bulk = Self::payload_kind(self.policy.bulk_precision());
            let now = self.clock.now();
            let owner: Vec<usize> = (0..m.n_experts).map(|e| self.effective_owner(e)).collect();
            // `replicated` means *landed*: an in-flight copy still costs
            // the wire wait this seam exists to route around.  A copy on a
            // dead device is no copy at all.
            let replicated = (0..m.n_experts)
                .map(|e| {
                    let key = PayloadKey { layer, expert: e };
                    devices.iter().enumerate().any(|(d, dev)| {
                        d != owner[e]
                            && self.device_alive(d)
                            && dev.cache.peek_ready_at(&key, bulk).is_some_and(|t| t <= now)
                    })
                })
                .collect();
            LayerPlacement { n_devices: devices.len(), owner, replicated }
        });
        let ctx = PlanCtx {
            probs,
            n_tokens: active.len(),
            n_experts: m.n_experts,
            top_k: m.top_k,
            active,
            ndp: self.ndp.is_some(),
            fp16_cached: &probe,
            predicted: self.predicted_scores.get(&layer).map(|v| v.as_slice()),
            precisions: self.alloc.as_ref().map(|a| a.layer(layer)),
            placement: placement.as_ref(),
        };
        self.policy.plan(&ctx)
    }

    /// Feed one layer's routing into the demand EWMAs — the precision
    /// allocator's (DESIGN.md §10) and the sharding replicator's (§11).
    /// Prefill and decode both count: prompt routing is the cheapest
    /// warm-up signal.
    fn observe_demand(&mut self, layer: usize, probs: &[f32], active: &[bool]) {
        let m = &self.model.manifest.model;
        let (n_experts, top_k, step) = (m.n_experts, m.top_k, self.decode_steps);
        let obs = LayerObservation { step, layer, n_experts, top_k, probs, active };
        if let Some(a) = self.alloc.as_mut() {
            a.observe(&obs);
        }
        if let Some(r) = self.replicator.as_mut() {
            r.observe(&obs);
        }
        if let Some(f) = self.faults.as_mut() {
            f.ewma.observe(&obs);
        }
    }

    /// Execute one layer's MoE (plan → transfers → experts → combine).
    /// Returns the MoE output accumulated on the host.
    fn run_moe_layer(
        &mut self,
        layer: usize,
        xn: &Tensor,
        plan: &LayerPlan,
        active: &[bool],
        prefill: bool,
        router_done: VTime,
    ) -> Result<Vec<f32>> {
        let m = self.dims;
        let n_rows = if prefill { m.t_prefill } else { m.b_max };
        let d = m.d_model;
        // Reuse the step-scratch accumulator (callers hand it back); a
        // clear + zero-fill resize is the old `vec![0f32; _]` semantics.
        let mut moe = std::mem::take(&mut self.scratch_moe);
        moe.clear();
        moe.resize(n_rows * d, 0f32);
        // Device 0's next dense stage waits on NDP round trips *and* on
        // remote devices shipping their expert outputs back.
        let mut combine_barrier = router_done;
        self.in_prefill = prefill;

        for exec in &plan.execs {
            let n_tok = exec.tokens.len();
            match exec.location {
                Location::Gpu => {
                    let key = PayloadKey { layer, expert: exec.expert };
                    let kind = Self::payload_kind(exec.precision);
                    let owner = self.effective_owner(exec.expert);
                    let dev = self.choose_device(&key, kind, owner, router_done);
                    // Cross-device dispatch: the hidden state lives on
                    // device 0; a remote exec ships activations out (and,
                    // below, back) on the peer links.  The weight fetch
                    // (if any) overlaps the activation hop — both are
                    // data-valid at router_done.
                    let act_bytes = self.cost.act_bytes_one_way(n_tok);
                    let act_in = if dev == 0 {
                        router_done
                    } else {
                        self.peer_transfer(
                            0,
                            dev,
                            router_done,
                            act_bytes,
                            TransferClass::Activations,
                        )
                    };
                    let (base, t_base) =
                        self.acquire_base(dev, layer, exec.expert, exec.precision, router_done)?;
                    let (comp, weights_ready) = match exec.precision {
                        Precision::IntComp(bits) => {
                            let (c, t_comp) =
                                self.acquire_comp(dev, layer, exec.expert, bits, router_done)?;
                            (Some(c), t_base.max(t_comp))
                        }
                        _ => (None, t_base),
                    };
                    let avg_rank = if comp.is_some() {
                        self.avg_ranks[layer][exec.expert]
                    } else {
                        0.0
                    };
                    let op = self.cost.expert_gpu(n_tok, exec.precision, avg_rank);
                    let gpu_free = self.devices[dev].gpu.free_at();
                    let ready = weights_ready.max(act_in);
                    let (start, end) = self.devices[dev].gpu.acquire(ready, op.seconds);
                    if !prefill {
                        // Decode critical-path stall: how long this exec's
                        // start was pushed past compute-and-data
                        // availability by waiting on weight/compensator
                        // transfers — the quantity prefetching (§8) and
                        // replication (§11) exist to shrink.
                        self.breakdown.transfer_stall_s += (start - gpu_free.max(act_in)).max(0.0);
                    }
                    self.breakdown.expert_compute_s += op.seconds;
                    self.devices[dev].execs += 1;
                    if dev != owner {
                        self.replica_serves += 1;
                    }
                    if dev != 0 {
                        self.remote_execs += 1;
                        let t_back =
                            self.peer_transfer(dev, 0, end, act_bytes, TransferClass::Activations);
                        combine_barrier = combine_barrier.max(t_back);
                    }
                    let refs: Vec<&Tensor> = match &comp {
                        Some(c) => base.iter().chain(c.iter()).collect(),
                        None => base.iter().collect(),
                    };
                    let y = self.model.run_expert(exec.precision, prefill, xn, &refs)?;
                    combine::accumulate(&mut moe, &y.y, exec, d);
                }
                Location::Ndp => {
                    // Activations out, near-data execute, activations back.
                    let act = self.cost.act_bytes_one_way(n_tok); // fp16 per direction
                    let link = self.ndp_link.as_mut().expect("ndp exec without ndp link");
                    let t_in = link.transfer(router_done, act, TransferClass::Activations);
                    let dev = self.ndp.as_mut().expect("ndp exec without device");
                    let op = self.cost.expert_ndp(n_tok, exec.precision, &dev.cfg);
                    let t_done = dev.execute_expert(&self.cost, t_in, n_tok, exec.precision);
                    self.breakdown.ndp_compute_s += op.seconds;
                    let link = self.ndp_link.as_mut().unwrap();
                    let t_back = link.transfer(t_done, act, TransferClass::Activations);
                    combine_barrier = combine_barrier.max(t_back);
                    // Numerics: same stage executed locally (weights are
                    // resident near-data; no PCIe charge).
                    let lits =
                        self.model
                            .payload_base(layer, exec.expert, exec.precision, self.method())?;
                    let refs: Vec<&Tensor> = lits.iter().collect();
                    let y = self.model.run_expert(exec.precision, prefill, xn, &refs)?;
                    combine::accumulate(&mut moe, &y.y, exec, d);
                }
            }
        }

        // Shared experts (DeepSeek-style): resident on device 0, fp16,
        // every token.
        let n_live = active.iter().filter(|&&a| a).count();
        for s in 0..m.n_shared {
            let op = self.cost.expert_gpu(n_live, Precision::Fp16, 0.0);
            self.devices[0].gpu.acquire(router_done, op.seconds);
            self.breakdown.expert_compute_s += op.seconds;
            let y = self.model.run_shared_expert(layer, s, prefill, xn)?;
            combine::accumulate_all(&mut moe, &y.y, active, d);
        }

        self.devices[0].gpu.sync_to(combine_barrier);
        Ok(moe)
    }

    /// Crate-visible planning seam for the teacher-forced scorer (same
    /// path as serving; was the `plan_layer_pub` test hook).
    pub(crate) fn plan_layer_for_scoring(
        &self,
        probs: &[f32],
        active: &[bool],
        layer: usize,
    ) -> LayerPlan {
        self.plan_layer(probs, active, layer)
    }

    /// Crate-visible MoE execution seam for the scorer (virtual time still
    /// advances, but scoring runs use a dedicated engine instance; was the
    /// `run_moe_layer_pub` test hook).
    pub(crate) fn run_moe_layer_for_scoring(
        &mut self,
        layer: usize,
        xn: &Tensor,
        plan: &LayerPlan,
        active: &[bool],
        prefill: bool,
    ) -> Result<Vec<f32>> {
        let t = self.clock.now();
        self.run_moe_layer(layer, xn, plan, active, prefill, t)
    }

    /// One decode step over all active slots.
    pub fn decode_step(&mut self) -> Result<()> {
        let m = self.dims;
        let (tokens, pos) = self.state.decode_inputs();
        let active = self.state.active_rows();
        let n_active = active.iter().filter(|&&a| a).count();
        if n_active == 0 {
            return Ok(());
        }
        let step_t0 = self.clock.now();
        self.prefetch.begin_step();
        // Decode-step boundary: apply due scripted faults (DESIGN.md §12)
        // *first* — the precision replan and the replica reconcile below
        // must see the post-fault fleet — then refresh the per-expert
        // precision plan from the routing demand accumulated so far
        // (DESIGN.md §10) and reconcile the fleet's pinned replica sets
        // against the same popularity table (DESIGN.md §11).
        let fault_loss = self.apply_faults();
        let stall_before_fault = self.breakdown.transfer_stall_s;
        if let Some(a) = self.alloc.as_mut() {
            a.replan();
        }
        // Elastic residency (DESIGN.md §15): reconcile resident rungs
        // against the fresh precision plan — demote in place for free,
        // promote hottest-first under the requant budget.  Runs after the
        // replan (it consumes the new plan) and before the replica
        // reconcile (replicas are priced at the bulk rung regardless).
        self.elastic_step()?;
        self.replicate_step()?;

        let mut x = self.model.embed(&tokens, false)?;
        let op = self.cost.embed(n_active);
        self.devices[0].gpu.acquire(step_t0, op.seconds);

        let ctx_total: usize = pos.iter().map(|&p| p as usize + 1).sum();
        for layer in 0..m.n_layers {
            let (x2, kc, vc) = self.model.attn_decode(
                layer,
                &x,
                &self.state.kv[layer].k,
                &self.state.kv[layer].v,
                &pos,
            )?;
            self.state.kv[layer] = LayerKv { k: kc, v: vc };
            let (xn, probs) = self.model.router(layer, &x2, false)?;
            let op = self.cost.attn_router(n_active, ctx_total);
            let (_, router_done) = self.devices[0].gpu.acquire(self.clock.now(), op.seconds);
            self.breakdown.attn_router_s += op.seconds;

            self.observe_demand(layer, &probs, &active);
            let plan = self.plan_layer(&probs, &active, layer);
            debug_assert!(combine::plan_is_partition(&plan, m.b_max, m.top_k, &active));

            if let Some(t) = self.trace.as_mut() {
                if active[0] {
                    let row = &probs[..m.n_experts];
                    let sel = crate::policies::plan::topk_renorm(row, m.top_k)
                        .into_iter()
                        .map(|(e, w, _)| (e, w))
                        .collect();
                    t.push(self.decode_steps as usize, layer, sel);
                }
            }

            let moe = self.run_moe_layer(layer, &xn, &plan, &active, false, router_done)?;
            let mut xh = x2.to_f32_vec()?;
            for (a, b) in xh.iter_mut().zip(&moe) {
                *a += b;
            }
            self.scratch_moe = moe;
            x = self.model.make_x(m.b_max, &xh)?;

            // Speculate on upcoming layers now that this layer's demand
            // transfers are queued (FIFO link ⇒ speculation yields to
            // demand) and the updated hidden state exists for the gate
            // lookahead (DESIGN.md §8).
            self.issue_prefetches(layer, &x, &probs, &active, router_done)?;
        }

        let logits = self.model.head(&x)?;
        let op = self.cost.head(n_active);
        self.devices[0].gpu.acquire(self.clock.now(), op.seconds);
        self.breakdown.head_s += op.seconds;

        self.end_step();
        let now = self.clock.now();

        // Greedy sampling + completion handling.
        for slot in 0..m.b_max {
            if let Some(seq) = self.state.slots[slot].as_mut() {
                let row = &logits[slot * m.vocab..(slot + 1) * m.vocab];
                let next = argmax(row) as i32;
                seq.tokens.push(next);
                self.total_generated += 1;
                let done = seq.done();
                self.emitted.push(EmittedToken {
                    request_id: seq.request_id,
                    token: next,
                    index: seq.generated() - 1,
                    at: now,
                    last: done,
                });
                if done {
                    let seq = self.state.release(slot).unwrap();
                    self.records.push(RequestRecord {
                        id: seq.request_id,
                        prompt_len: seq.prompt_len,
                        generated: seq.generated(),
                        arrival: seq.arrival,
                        first_token_at: seq.first_token_at.unwrap_or(now),
                        finished_at: now,
                    });
                }
            }
        }
        // Attribute the loss step's extra weight stall to the recovery
        // window — the spike the chaos goldens pin as bounded.
        if fault_loss {
            let spike = self.breakdown.transfer_stall_s - stall_before_fault;
            if let Some(fs) = self.faults.as_mut() {
                fs.report.recovery_stall_s += spike;
            }
        }
        self.decode_steps += 1;
        Ok(())
    }

    /// The full-sequence forward pass behind [`ServeEngine::prefill`]
    /// and [`ServeEngine::resume`]: embed → per-layer attention/KV
    /// install/router/MoE → head on the last position → `end_step`.
    /// Returns the next token sampled for `slot`.  The caller owns slot
    /// bookkeeping (admit/emit/counters) so both entry points share one
    /// byte-identical op sequence.
    fn prefill_pass(&mut self, slot: usize, tokens: &[i32]) -> Result<i32> {
        let m = self.dims;
        let plen = tokens.len().min(m.t_prefill);
        let step_t0 = self.clock.now();

        let mut toks = tokens[..plen].to_vec();
        toks.resize(m.t_prefill, 0);
        let mut x = self.model.embed(&toks, true)?;
        self.devices[0].gpu.acquire(step_t0, self.cost.embed(plen).seconds);

        let active: Vec<bool> = (0..m.t_prefill).map(|i| i < plen).collect();
        let ctx_total = plen * (plen + 1) / 2;
        for layer in 0..m.n_layers {
            let (x2, kc, vc) = self.model.attn_prefill(layer, &x)?;
            self.state.install_prefill(slot, layer, &kc, &vc)?;
            let (xn, probs) = self.model.router(layer, &x2, true)?;
            let op = self.cost.attn_router(plen, ctx_total);
            let (_, router_done) = self.devices[0].gpu.acquire(self.clock.now(), op.seconds);
            self.breakdown.attn_router_s += op.seconds;

            self.observe_demand(layer, &probs, &active);
            let plan = self.plan_layer(&probs, &active, layer);
            let moe = self.run_moe_layer(layer, &xn, &plan, &active, true, router_done)?;
            let mut xh = x2.to_f32_vec()?;
            for (a, b) in xh.iter_mut().zip(&moe) {
                *a += b;
            }
            self.scratch_moe = moe;
            x = self.model.make_x(m.t_prefill, &xh)?;
        }

        // Next token from the last position's hidden state.
        let xh = x.to_f32_vec()?;
        let mut batch_x = vec![0f32; m.b_max * m.d_model];
        batch_x[slot * m.d_model..(slot + 1) * m.d_model]
            .copy_from_slice(&xh[(plen - 1) * m.d_model..plen * m.d_model]);
        let x_lit = self.model.make_x(m.b_max, &batch_x)?;
        let logits = self.model.head(&x_lit)?;
        self.devices[0].gpu.acquire(self.clock.now(), self.cost.head(1).seconds);

        self.end_step();
        Ok(argmax(&logits[slot * m.vocab..(slot + 1) * m.vocab]) as i32)
    }

    /// Prefill one request into `slot` (its own virtual step).
    pub fn prefill(&mut self, slot: usize, req: &Request) -> Result<()> {
        self.state.admit(slot, req, self.clock.now());
        let next = self.prefill_pass(slot, &req.prompt)?;
        let now = self.clock.now();
        let seq = self.state.slots[slot].as_mut().unwrap();
        seq.tokens.push(next);
        seq.first_token_at = Some(now);
        self.emitted.push(EmittedToken {
            request_id: seq.request_id,
            token: next,
            index: 0,
            at: now,
            last: seq.done(),
        });
        self.total_generated += 1;
        self.prefills += 1;
        Ok(())
    }

    /// Re-admit a preempted sequence into `slot` (DESIGN.md §13): a
    /// fresh prefill pass over prompt *plus* already-generated tokens
    /// rebuilds the KV cache, then one more token is sampled and
    /// emitted.  `first_token_at` is preserved — TTFT was already paid.
    /// Like `prefill`, a sequence that completes here keeps its slot
    /// until the next decode step releases it and records completion.
    pub(crate) fn resume(&mut self, slot: usize, seq: ActiveSeq) -> Result<()> {
        debug_assert!(self.state.slots[slot].is_none(), "resume into an occupied slot");
        let tokens = seq.tokens.clone();
        self.state.slots[slot] = Some(seq);
        let next = self.prefill_pass(slot, &tokens)?;
        let now = self.clock.now();
        let seq = self.state.slots[slot].as_mut().unwrap();
        seq.tokens.push(next);
        let done = seq.done();
        self.emitted.push(EmittedToken {
            request_id: seq.request_id,
            token: next,
            index: seq.generated() - 1,
            at: now,
            last: done,
        });
        self.total_generated += 1;
        self.prefills += 1;
        Ok(())
    }

    /// Observe layer `layer`'s routing and issue budgeted speculative
    /// transfers for the layers the predictor expects next (DESIGN.md §8).
    /// `x_next` is the layer's *output* hidden (the residual stream the
    /// gate lookahead scores); `router_done` is the earliest data-valid
    /// time for speculation this layer.
    fn issue_prefetches(
        &mut self,
        layer: usize,
        x_next: &Tensor,
        probs: &[f32],
        active: &[bool],
        router_done: VTime,
    ) -> Result<()> {
        let Some(mut pred) = self.predictor.take() else {
            return Ok(());
        };
        let out = self.issue_with(pred.as_mut(), layer, x_next, probs, active, router_done);
        self.predictor = Some(pred);
        out
    }

    fn issue_with(
        &mut self,
        pred: &mut dyn ExpertPredictor,
        layer: usize,
        x_next: &Tensor,
        probs: &[f32],
        active: &[bool],
        router_done: VTime,
    ) -> Result<()> {
        let m = self.dims;
        pred.observe(&LayerObservation {
            step: self.decode_steps,
            layer,
            n_experts: m.n_experts,
            top_k: m.top_k,
            probs,
            active,
        });
        // A predictor exists (the caller took it out of `self.predictor`)
        // but the numeric knobs may still forbid issuing.
        if !self.prefetch_cfg.issuable() {
            return Ok(());
        }
        // Speculate the policy's *bulk* payload only: compensators are
        // token-dependent and tiny, so they stay on demand.
        let prec = self.policy.bulk_precision();
        let kind = Self::payload_kind(prec);
        let bytes_each = self.base_bytes(prec);
        let n_active = active.iter().filter(|&&a| a).count();
        // max-then-min, not `clamp` — the same latent panic the EWMA
        // predictor's cap had when a dense config routes top_k > n_experts.
        let cap = (n_active * m.top_k).max(m.top_k).min(m.n_experts);

        for depth in 1..=self.prefetch_cfg.lookahead.min(m.n_layers) {
            // Budget gone: don't burn router stages on predictions we
            // could never issue (the scores are advisory only).
            if self.prefetch.budget_left() < bytes_each {
                break;
            }
            // Past the last layer the lookahead wraps to the next decode
            // step's early layers.
            let lf = layer + depth;
            let (t_layer, t_step) = if lf < m.n_layers {
                (lf, self.decode_steps)
            } else {
                (lf - m.n_layers, self.decode_steps + 1)
            };
            // The gate lookahead scores the target layer's router on the
            // current residual stream — host-side math on an idle-tiny
            // GEMV (d × E ≪ one attention), so no virtual-time charge.
            let la_probs: Option<Vec<f32>> = if pred.wants_lookahead() {
                Some(self.model.router(t_layer, x_next, false)?.1)
            } else {
                None
            };
            let ctx = PredictCtx {
                step: t_step,
                layer: t_layer,
                n_experts: m.n_experts,
                top_k: m.top_k,
                active,
                lookahead_probs: la_probs.as_deref(),
            };
            let ranked = pred.predict(&ctx);
            // Recycle the layer's previous score Vec instead of allocating
            // a fresh dense table every lookahead depth of every layer.
            let mut dense = self.predicted_scores.remove(&t_layer).unwrap_or_default();
            dense.clear();
            dense.resize(m.n_experts, 0f64);
            for p in &ranked {
                dense[p.expert] = p.score;
            }
            self.predicted_scores.insert(t_layer, dense);

            for p in ranked.into_iter().take(cap) {
                let key = PayloadKey { layer: t_layer, expert: p.expert };
                // Dedup against resident payloads and in-flight fetches
                // anywhere in the fleet (a landed replica is as good as a
                // local copy — the router will pick it).
                if self.devices.iter().any(|d| d.cache.contains(&key, kind)) {
                    continue;
                }
                if !self.prefetch.try_spend(bytes_each) {
                    return Ok(()); // step budget exhausted
                }
                // Speculation lands on the expert's (effective) owner
                // device, over its own host link — never on a dead device.
                let dev = self.effective_owner(p.expert);
                let lits =
                    Arc::new(self.model.payload_base(t_layer, p.expert, prec, self.method())?);
                let done = self.devices[dev].host_link.transfer(
                    router_done,
                    bytes_each,
                    TransferClass::Speculative,
                );
                self.devices[dev].cache.insert_speculative(key, kind, lits, bytes_each, done);
                self.prefetch.issued += 1;
            }
        }
        Ok(())
    }

    /// Decode-step-boundary replica reconcile (DESIGN.md §11): turn the
    /// popularity table into each device's desired pinned set, discard
    /// stale replicas (free), and transfer the missing ones — from the
    /// owner's landed copy over the dev→dev peer link when possible,
    /// otherwise from host memory over the target's host link — under
    /// `TransferClass::Replication`.  No-op when `D = 1` or budget 0.
    fn replicate_step(&mut self) -> Result<()> {
        let Some(mut rep) = self.replicator.take() else {
            return Ok(());
        };
        let out = self.replicate_with(&mut rep);
        self.replicator = Some(rep);
        out
    }

    fn replicate_with(&mut self, rep: &mut Replicator) -> Result<()> {
        let prec = self.policy.bulk_precision();
        let kind = Self::payload_kind(prec);
        let bulk = self.base_bytes(prec);
        let now = self.clock.now();
        let n_devices = self.devices.len();
        // Ownership is the *effective* assignment (re-owning overlay over
        // the topology) — one authority for the shard rule, shared with
        // routing and the peer-sourcing check below.  Dead devices neither
        // receive replicas nor serve as sources.
        let alive: Vec<bool> = (0..n_devices).map(|d| self.device_alive(d)).collect();
        let plan = rep.plan_alive(bulk, |e| self.effective_owner(e), &alive);

        // Scratch-backed desired sets: the reconcile runs every decode
        // step, so the per-device `HashSet`s (and the pinned listing
        // below) reuse their previous step's allocations.
        let mut desired = std::mem::take(&mut self.scratch_desired);
        desired.resize_with(n_devices, HashSet::new);
        for want in desired.iter_mut() {
            want.clear();
        }
        for t in &plan {
            desired[t.device].insert((PayloadKey { layer: t.layer, expert: t.expert }, kind));
        }
        // Stale replicas are discards — no link traffic to free HBM.
        let mut pinned = std::mem::take(&mut self.scratch_pinned);
        for (dev, want) in desired.iter().enumerate() {
            self.devices[dev].cache.pinned_keys_into(&mut pinned);
            for &(key, k) in &pinned {
                if !want.contains(&(key, k)) {
                    self.devices[dev].cache.unpin(&key, k);
                }
            }
        }
        self.scratch_pinned = pinned;
        // Place missing replicas hottest-first (the plan's order).  A key
        // already resident on the target — pinned from an earlier step, or
        // demand-cached — is sticky: no re-transfer while it lives.
        for t in &plan {
            let key = PayloadKey { layer: t.layer, expert: t.expert };
            if self.devices[t.device].cache.contains(&key, kind) {
                continue;
            }
            let owner = self.effective_owner(t.expert);
            let lits = Arc::new(self.model.payload_base(t.layer, t.expert, prec, self.method())?);
            let owner_has_landed = owner != t.device
                && self.devices[owner].cache.peek_ready_at(&key, kind).is_some_and(|r| r <= now);
            // Peer-sourced copies record their source device so that, if
            // the source dies mid-copy, the in-flight entry is dropped and
            // requeued instead of advertising a landing the dead wire can
            // never honor.
            let (done, src) = if owner_has_landed {
                let t_done =
                    self.peer_transfer(owner, t.device, now, bulk, TransferClass::Replication);
                (t_done, Some(owner))
            } else {
                let t_done = self.devices[t.device].host_link.transfer(
                    now,
                    bulk,
                    TransferClass::Replication,
                );
                (t_done, None)
            };
            self.devices[t.device].cache.insert_pinned_from(key, kind, lits, bulk, done, src);
            rep.issued += 1;
            rep.bytes_moved += bulk;
        }
        self.scratch_desired = desired;
        Ok(())
    }

    /// Decode-step-boundary elastic reconcile (DESIGN.md §15): diff each
    /// expert's resident rung on its owner device against the allocator's
    /// fresh plan, demote over-provisioned residents in place (free — a
    /// requantize-on-device, no link traffic) and promote under-provisioned
    /// ones hottest-first, paying only the delta bytes between rungs on the
    /// owner's host link under `TransferClass::Promotion`, capped by the
    /// requant budget.  No-op at zero budget or without an allocator —
    /// none of this wiring runs then, keeping the legacy serve
    /// byte-identical.
    fn elastic_step(&mut self) -> Result<()> {
        if !self.elastic_active() {
            return Ok(());
        }
        let m = self.dims;
        let now = self.clock.now();
        // Scratch-backed rung table: cleared and refilled each boundary
        // instead of reallocating `n_layers` fresh rows.
        let mut resident = std::mem::take(&mut self.scratch_resident);
        resident.resize_with(m.n_layers, Vec::new);
        for row in resident.iter_mut() {
            row.clear();
            row.resize(m.n_experts, None);
        }
        for (layer, row) in resident.iter_mut().enumerate() {
            for (expert, slot) in row.iter_mut().enumerate() {
                let owner = self.effective_owner(expert);
                if !self.device_alive(owner) {
                    continue;
                }
                let key = PayloadKey { layer, expert };
                *slot =
                    Self::resident_precision(&self.devices[owner].cache.level_info(&key), now);
            }
        }
        let alloc = self.alloc.as_ref().expect("elastic_active implies allocator");
        let actions = alloc.elastic_actions(&resident, self.policy_cfg.requant_budget_bytes);
        for act in actions {
            match act {
                ElasticAction::Demote { layer, expert, to, .. } => {
                    self.demote_resident(layer, expert, to, now)?;
                }
                ElasticAction::Promote { layer, expert, to, delta, .. } => {
                    self.promote_resident(layer, expert, to, delta, now)?;
                }
            }
        }
        self.scratch_resident = resident;
        Ok(())
    }

    /// The precision rung an entry's *landed* levels currently serve:
    /// fp16 wins outright; otherwise the widest quant base, compensated
    /// when its same-width factors landed too.  In-flight levels don't
    /// count — their data isn't on-device yet.
    fn resident_precision(levels: &[(PayloadKind, usize, VTime)], now: VTime) -> Option<Precision> {
        let landed = |k: PayloadKind| levels.iter().any(|&(lk, _, r)| lk == k && r <= now);
        if landed(PayloadKind::Fp16) {
            return Some(Precision::Fp16);
        }
        let widest = levels
            .iter()
            .filter_map(|&(k, _, r)| match k {
                PayloadKind::Quant(b) if r <= now => Some(b),
                _ => None,
            })
            .max()?;
        if landed(PayloadKind::Comp(widest)) {
            Some(Precision::IntComp(widest))
        } else {
            Some(Precision::Int(widest))
        }
    }

    /// Apply one planned demotion on `expert`'s owner device: drop every
    /// level outside the target rung (counted in the cache's demotion
    /// ledger) and materialize the target's missing levels at zero link
    /// cost — requantizing the resident higher-precision copy on-device.
    fn demote_resident(
        &mut self,
        layer: usize,
        expert: usize,
        to: Precision,
        now: VTime,
    ) -> Result<()> {
        let dev = self.effective_owner(expert);
        let key = PayloadKey { layer, expert };
        let base_kind = Self::payload_kind(to);
        let comp_kind = match to {
            Precision::IntComp(b) => Some(PayloadKind::Comp(b)),
            _ => None,
        };
        // Drop first, so the zero-cost materialization below never trips
        // eviction pressure against other experts.
        for (kind, _, _) in self.devices[dev].cache.level_info(&key) {
            if kind != base_kind && Some(kind) != comp_kind {
                self.devices[dev].cache.drop_level(&key, kind);
            }
        }
        if !self.devices[dev].cache.contains(&key, base_kind) {
            let lits = Arc::new(self.model.payload_base(layer, expert, to, self.method())?);
            let bytes = self.base_bytes(to);
            self.devices[dev].cache.insert_ready(key, base_kind, lits, bytes, now);
        }
        if let (Some(kind), Precision::IntComp(bits)) = (comp_kind, to) {
            if !self.devices[dev].cache.contains(&key, kind) {
                let tag = &self.policy_cfg.comp_tag;
                let lits = Arc::new(self.model.payload_comp(layer, expert, bits, tag)?);
                let bytes = self.model.manifest.comp_bytes(tag, bits, layer, expert);
                self.devices[dev].cache.insert_ready(key, kind, lits, bytes, now);
            }
        }
        Ok(())
    }

    /// Apply one planned promotion on `expert`'s owner device: move only
    /// the delta bytes between the resident and target rungs over the
    /// owner's host link (`TransferClass::Promotion`), install the target
    /// levels landing when the delta does, and fold the now-stale lower
    /// levels (the cache's supersede ledger).
    fn promote_resident(
        &mut self,
        layer: usize,
        expert: usize,
        to: Precision,
        delta: usize,
        now: VTime,
    ) -> Result<()> {
        let dev = self.effective_owner(expert);
        let key = PayloadKey { layer, expert };
        let base_kind = Self::payload_kind(to);
        let done = self.devices[dev].host_link.transfer(now, delta, TransferClass::Promotion);
        if !self.devices[dev].cache.contains(&key, base_kind) {
            let lits = Arc::new(self.model.payload_base(layer, expert, to, self.method())?);
            let bytes = self.base_bytes(to);
            self.devices[dev].cache.insert_ready(key, base_kind, lits, bytes, done);
        }
        if let Precision::IntComp(bits) = to {
            let kind = PayloadKind::Comp(bits);
            if !self.devices[dev].cache.contains(&key, kind) {
                let tag = &self.policy_cfg.comp_tag;
                let lits = Arc::new(self.model.payload_comp(layer, expert, bits, tag)?);
                let bytes = self.model.manifest.comp_bytes(tag, bits, layer, expert);
                self.devices[dev].cache.insert_ready(key, kind, lits, bytes, done);
            }
        }
        self.devices[dev].cache.supersede(&key, base_kind);
        self.elastic_promotions += 1;
        self.elastic_promoted_bytes += delta;
        Ok(())
    }

    fn end_step(&mut self) {
        let mut resources: Vec<&mut Resource> = Vec::new();
        for d in self.devices.iter_mut() {
            resources.push(&mut d.gpu);
            resources.push(&mut d.host_link.resource);
        }
        for l in self.peer.iter_mut().flatten().flatten() {
            resources.push(&mut l.resource);
        }
        if let Some(l) = self.ndp_link.as_mut() {
            resources.push(&mut l.resource);
        }
        if let Some(n) = self.ndp.as_mut() {
            resources.push(&mut n.compute);
        }
        self.clock.end_step(&mut resources);
    }

    pub fn now(&self) -> VTime {
        self.clock.now()
    }

    pub fn report(&self) -> Report {
        let mut bytes = std::collections::HashMap::new();
        let mut breakdown = self.breakdown.clone();
        // Every link in the deployment: per-device host links, the peer
        // mesh, and the NDP link — `D = 1` reduces to the old pcie(+ndp).
        let mut logs: Vec<&TransferLog> = self.devices.iter().map(|d| &d.host_link.log).collect();
        for l in self.peer.iter().flatten().flatten() {
            logs.push(&l.log);
        }
        if let Some(l) = self.ndp_link.as_ref() {
            logs.push(&l.log);
        }
        for (name, class) in [
            ("expert_weights", TransferClass::ExpertWeights),
            ("compensator", TransferClass::Compensator),
            ("activations", TransferClass::Activations),
            ("speculative_weights", TransferClass::Speculative),
            ("replication", TransferClass::Replication),
            ("promotion", TransferClass::Promotion),
        ] {
            let total: usize = logs.iter().map(|log| log.bytes_of(class)).sum();
            bytes.insert(name.to_string(), total);
        }
        let busy = |class: TransferClass| -> f64 {
            logs.iter()
                .flat_map(|log| log.events.iter())
                .filter(|e| e.class == class)
                .map(|e| e.end - e.start)
                .sum()
        };
        breakdown.transfer_weights_s = busy(TransferClass::ExpertWeights);
        breakdown.transfer_comp_s = busy(TransferClass::Compensator);
        breakdown.transfer_spec_s = busy(TransferClass::Speculative);
        breakdown.transfer_repl_s = busy(TransferClass::Replication);
        breakdown.transfer_promo_s = busy(TransferClass::Promotion);
        breakdown.transfer_act_s = busy(TransferClass::Activations);

        Report {
            policy: self.policy.name().to_string(),
            model: self.model.manifest.model.name.clone(),
            n_requests: self.records.len(),
            total_generated: self.total_generated,
            virtual_seconds: self.clock.now(),
            wall_seconds: self.started.elapsed().as_secs_f64(),
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            breakdown,
            bytes,
            cache_hit_rate: self.fleet_hit_rate(),
            requests: self.records.clone(),
            backend_execs: self.model.backend().exec_count(),
            prefetch: PrefetchReport {
                predictor: self
                    .predictor
                    .as_ref()
                    .map(|p| p.name())
                    .unwrap_or("off")
                    .to_string(),
                issued: self.prefetch.issued,
                covered: self.prefetch.covered,
                demand_fetches: self.prefetch.demand_fetches,
                speculative_bytes: self
                    .devices
                    .iter()
                    .map(|d| d.host_link.log.bytes_of(TransferClass::Speculative))
                    .sum(),
                wasted_bytes: self
                    .devices
                    .iter()
                    .map(|d| {
                        d.cache.wasted_speculative_bytes
                            + d.cache.resident_unused_speculative_bytes()
                    })
                    .sum(),
            },
            alloc: self.alloc.as_ref().map(|a| a.report()),
            shard: (self.devices.len() > 1).then(|| ShardReport {
                devices: self.devices.len(),
                replicate_budget_bytes: self.cost.sys.shard.replicate_budget_bytes,
                replicas_issued: self.replicator.as_ref().map_or(0, |r| r.issued),
                replication_bytes: self.replicator.as_ref().map_or(0, |r| r.bytes_moved),
                replica_serves: self.replica_serves,
                remote_execs: self.remote_execs,
                demand_fetches_per_device: self.devices.iter().map(|d| d.demand_fetches).collect(),
                execs_per_device: self.devices.iter().map(|d| d.execs).collect(),
            }),
            fault: self.faults.as_ref().map(|f| f.report.clone()),
            // The scheduling ledger is the Server's to attach (the
            // engine has no tenancy notion); `None` here keeps the
            // legacy report byte-identical.
            sched: None,
            elastic: self.elastic_active().then(|| ElasticReport {
                requant_budget_bytes: self.policy_cfg.requant_budget_bytes,
                demotions: self.devices.iter().map(|d| d.cache.demotions).sum(),
                demoted_bytes: self.devices.iter().map(|d| d.cache.demoted_bytes).sum(),
                promotions: self.elastic_promotions,
                promoted_bytes: self.elastic_promoted_bytes,
                demand_promotions: self.elastic_demand_promotions,
                superseded: self.devices.iter().map(|d| d.cache.superseded).sum(),
                superseded_bytes: self.devices.iter().map(|d| d.cache.superseded_bytes).sum(),
            }),
        }
    }
}

/// Greedy sampling argmax, first index on ties; `total_cmp` keeps it
/// panic-free (and deterministic) even on NaN-poisoned logits.
pub(crate) fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}

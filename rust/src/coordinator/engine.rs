//! `ServeEngine` — the decode/prefill machinery.
//!
//! Each step runs real numerics through the model stages (the pluggable
//! numerics backend — reference or PJRT, DESIGN.md §4) while advancing
//! virtual time against the simulated testbed:
//!
//! ```text
//!   embed ─► for each layer:                         (GPU resource)
//!              attn ─► router ─► policy.plan()
//!              per exec:   [link: weights(+comp) if cache miss] ─► GPU FFN
//!                       or [ndp-link: acts] ─► NDP FFN ─► [acts back]
//!              combine (host) ─► barrier
//!          ─► head ─► sample
//! ```
//!
//! Transfers and compute acquire different virtual resources, so expert
//! *i*'s compute overlaps expert *i+1*'s transfer exactly as the real
//! pipelined fetch does.  All byte counts come from the manifest's
//! transfer tables (true packed sizes — DESIGN.md §7).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::backend::Tensor;
use crate::config::{PolicyConfig, Precision, PrefetchConfig, SystemConfig};
use crate::coordinator::combine;
use crate::coordinator::metrics::{PrefetchReport, Report, RequestRecord, StepBreakdown};
use crate::coordinator::state::{ActiveSeq, BatchState, LayerKv};
use crate::offload::cache::{ExpertCache, PayloadKey, PayloadKind};
use crate::offload::ndp::NdpDevice;
use crate::offload::prefetch::PrefetchQueue;
use crate::offload::transfer::{Link, TransferClass};
use crate::policies::make_policy;
use crate::policies::plan::{LayerPlan, Location, PlanCtx, Policy};
use crate::predict::{make_predictor, ExpertPredictor, LayerObservation, PredictCtx};
use crate::quant::alloc::PrecisionAllocator;
use crate::runtime::StagedModel;
use crate::sim::clock::{Resource, VTime, VirtualClock};
use crate::sim::CostModel;
use crate::workload::{DecodeTrace, Request};

/// One generated token tagged for the session layer (`server::Server`
/// drains these after every step and routes them into `TokenEvent`
/// streams).
#[derive(Debug, Clone, Copy)]
pub(crate) struct EmittedToken {
    pub request_id: u64,
    pub token: i32,
    /// 0-based index among the request's generated tokens.
    pub index: usize,
    /// Virtual time the step that produced the token completed.
    pub at: VTime,
    /// This token completes the request.
    pub last: bool,
}

/// Read-only snapshot of engine progress (the façade's replacement for
/// the `pub` fields `ServeEngine` no longer exposes).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub virtual_now: VTime,
    pub decode_steps: u64,
    pub prefills: u64,
    pub total_generated: usize,
    /// Batch slots currently bound to live sequences.
    pub active_slots: usize,
    /// Requests that ran to completion (cancelled ones excluded).
    pub completed_requests: usize,
}

/// Read-only view of the expert cache's economics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheView {
    pub entries: usize,
    pub used_bytes: usize,
    pub capacity_bytes: usize,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub hit_rate: f64,
}

pub struct ServeEngine {
    model: StagedModel,
    policy_cfg: PolicyConfig,
    policy: Box<dyn Policy>,
    cost: CostModel,
    gpu: Resource,
    pcie: Link,
    ndp: Option<NdpDevice>,
    ndp_link: Option<Link>,
    cache: ExpertCache,
    pub(crate) clock: VirtualClock,
    pub(crate) state: BatchState,
    breakdown: StepBreakdown,
    /// [layer][expert] mean true compensator rank (cost model input).
    avg_ranks: Vec<Vec<f64>>,
    trace: Option<DecodeTrace>,
    /// Prefetch knobs (DESIGN.md §8); `PrefetchConfig::off()` reproduces
    /// the demand-only loop byte-for-byte.
    prefetch_cfg: PrefetchConfig,
    predictor: Option<Box<dyn ExpertPredictor>>,
    /// Speculative-transfer budget/coverage bookkeeping.
    prefetch: PrefetchQueue,
    /// layer → dense predictor scores, refreshed as predictions are made
    /// (surfaced to policies through `PlanCtx::predicted`).
    predicted_scores: HashMap<usize, Vec<f64>>,
    /// Budgeted per-expert precision allocator (DESIGN.md §10) — present
    /// only when the policy consumes its plan (`wants_precision_plan`).
    /// Re-plans at decode-step boundaries; its per-layer map reaches the
    /// policy through `PlanCtx::precisions`.
    alloc: Option<PrecisionAllocator>,
    /// The MoE layer currently executing belongs to a prefill step
    /// (prefetch stats track the decode critical path only).
    in_prefill: bool,
    decode_steps: u64,
    prefills: u64,
    total_generated: usize,
    records: Vec<RequestRecord>,
    /// Tokens generated since the session layer last drained.
    emitted: Vec<EmittedToken>,
    started: Instant,
}

impl ServeEngine {
    /// Demand-only engine (no speculation) — the seed behaviour.
    pub fn new(model: StagedModel, policy_cfg: PolicyConfig, sys: SystemConfig) -> Result<Self> {
        Self::with_prefetch(model, policy_cfg, sys, PrefetchConfig::off())
    }

    /// Engine with a speculative prefetch subsystem (DESIGN.md §8).
    pub fn with_prefetch(
        model: StagedModel,
        policy_cfg: PolicyConfig,
        sys: SystemConfig,
        prefetch_cfg: PrefetchConfig,
    ) -> Result<Self> {
        let dims = model.manifest.model.clone();
        let cost = CostModel::new(sys.clone(), dims.clone());
        let state = BatchState::new(&model)?;
        let avg_ranks = Self::rank_table(&model, &policy_cfg.comp_tag)?;
        let ndp = sys.ndp.as_ref().map(|n| NdpDevice::new(n.clone()));
        let ndp_link = sys
            .ndp
            .as_ref()
            .map(|n| Link::new("ndp-link", n.link_bw, n.link_lat));
        let predictor = make_predictor(&prefetch_cfg.predictor, dims.n_layers, dims.n_experts)?;
        let policy = make_policy(&policy_cfg)?;
        let alloc = if policy.wants_precision_plan() {
            // `cfg.bits` is the adaptive floor: the ladder never serves an
            // expert below it (and fails fast if the artifact cannot).
            Some(PrecisionAllocator::new(
                &model.manifest,
                &policy_cfg.comp_tag,
                policy_cfg.bits,
                policy_cfg.alloc_budget_bytes,
            )?)
        } else {
            None
        };
        let mut engine = ServeEngine {
            policy,
            policy_cfg,
            cost,
            gpu: Resource::new("gpu"),
            pcie: Link::new("pcie", sys.pcie_bw, sys.pcie_lat),
            ndp,
            ndp_link,
            cache: ExpertCache::new(sys.gpu_cache_bytes),
            clock: VirtualClock::new(),
            state,
            breakdown: StepBreakdown::default(),
            avg_ranks,
            trace: None,
            prefetch: PrefetchQueue::new(prefetch_cfg.budget_bytes),
            prefetch_cfg,
            predictor,
            predicted_scores: HashMap::new(),
            alloc,
            in_prefill: false,
            decode_steps: 0,
            prefills: 0,
            total_generated: 0,
            records: Vec::new(),
            emitted: Vec::new(),
            started: Instant::now(),
            model,
        };
        engine.prewarm()?;
        Ok(engine)
    }

    // -- read-only façade (DESIGN.md §9): the fields behind these used to
    // be `pub`; binaries/examples/figures now go through `server::Server`,
    // which forwards here -------------------------------------------------

    /// The staged model this engine serves (manifest, stages, store).
    pub fn model(&self) -> &StagedModel {
        &self.model
    }

    /// The policy knob set the engine was built with.
    pub fn policy_config(&self) -> &PolicyConfig {
        &self.policy_cfg
    }

    /// The prefetch knob set the engine was built with.
    pub fn prefetch_config(&self) -> &PrefetchConfig {
        &self.prefetch_cfg
    }

    /// Snapshot of serve-loop progress.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            virtual_now: self.clock.now(),
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            total_generated: self.total_generated,
            active_slots: self.state.n_active(),
            completed_requests: self.records.len(),
        }
    }

    /// Snapshot of the expert cache's economics.
    pub fn cache_view(&self) -> CacheView {
        CacheView {
            entries: self.cache.len(),
            used_bytes: self.cache.used_bytes(),
            capacity_bytes: self.cache.capacity(),
            hits: self.cache.hits,
            misses: self.cache.misses,
            evictions: self.cache.evictions,
            hit_rate: self.cache.hit_rate(),
        }
    }

    /// Record decode routing from now on (the Fig. 2 trace and the
    /// oracle-replay recording pass).
    pub fn record_trace(&mut self) {
        self.trace = Some(DecodeTrace::default());
    }

    /// Take the recorded decode trace; contextful error when tracing was
    /// never enabled (the old `trace.take().unwrap()` panic path).
    pub fn take_trace(&mut self) -> Result<DecodeTrace> {
        self.trace
            .take()
            .context("no decode trace recorded — call record_trace() before serving")
    }

    /// Install the recorded trace a trace-replaying predictor (e.g.
    /// `oracle`) replays; no-op for predictors that learn online.
    pub fn set_oracle_trace(&mut self, trace: &DecodeTrace) {
        if let Some(p) = self.predictor.as_mut() {
            p.install_trace(trace);
        }
    }

    /// Does the configured predictor need a recorded trace installed
    /// before serving ([`ServeEngine::set_oracle_trace`])?
    pub fn needs_recorded_trace(&self) -> bool {
        self.predictor.as_ref().is_some_and(|p| p.wants_trace())
    }

    /// Can this run ever issue a speculative transfer?  Ground truth for
    /// "is prefetching on": a predictor was actually constructed (the
    /// registry's call — an off-like name builds `None`) *and* the
    /// numeric knobs permit issuing.
    pub fn speculation_active(&self) -> bool {
        self.predictor.is_some() && self.prefetch_cfg.issuable()
    }

    /// Tokens generated since the last drain (session-event seam).
    pub(crate) fn take_emitted(&mut self) -> Vec<EmittedToken> {
        std::mem::take(&mut self.emitted)
    }

    /// Drop undelivered per-token events (the legacy `serve` loop has no
    /// session layer; without this a long run would retain one entry per
    /// generated token).
    pub(crate) fn discard_emitted(&mut self) {
        self.emitted.clear();
    }

    /// Slot currently bound to `request_id`, if any.
    pub(crate) fn slot_of(&self, request_id: u64) -> Option<usize> {
        self.state
            .slots
            .iter()
            .position(|s| s.as_ref().is_some_and(|q| q.request_id == request_id))
    }

    /// Release `slot` without recording a completion (session cancel).
    pub(crate) fn cancel_slot(&mut self, slot: usize) -> Option<ActiveSeq> {
        self.state.release(slot)
    }

    /// Policies may pin FP16 experts in GPU HBM at model-load time (the
    /// MoNDE hot/cold split of Kim et al. 2024); no link charge.
    /// Layer-major order is a stable stand-in for offline hotness ranking.
    fn prewarm(&mut self) -> Result<()> {
        if !self.policy.prewarm_fp16() {
            return Ok(());
        }
        let dims = self.model.manifest.model.clone();
        let bytes = self.model.manifest.transfer.fp16_expert_bytes;
        'outer: for layer in 0..dims.n_layers {
            for expert in 0..dims.n_experts {
                if self.cache.used_bytes() + bytes > self.cache.capacity() {
                    break 'outer;
                }
                let key = PayloadKey { layer, expert, kind: PayloadKind::Fp16 };
                let lits =
                    Arc::new(self.model.payload_base(layer, expert, Precision::Fp16, "hqq")?);
                self.cache.insert(key, lits, bytes);
            }
        }
        Ok(())
    }

    fn rank_table(model: &StagedModel, tag: &str) -> Result<Vec<Vec<f64>>> {
        let m = &model.manifest.model;
        let mut out = vec![vec![0f64; m.n_experts]; m.n_layers];
        if let Some(entry) = model.manifest.rank_table.get(tag) {
            for (key, rank) in model.manifest.mat_keys.iter().zip(&entry.ranks) {
                let mut it = key.split('.');
                let l: usize = it.next().context("mat key")?.parse()?;
                let e: usize = it.next().context("mat key")?.parse()?;
                out[l][e] += *rank as f64 / 3.0;
            }
        }
        Ok(out)
    }

    /// Quantizer family for payloads: GPTQ only when explicitly selected
    /// via the comp-free accuracy baselines; BEAM ships HQQ (paper §3.1).
    fn method(&self) -> String {
        self.policy_cfg.method.clone()
    }

    fn payload_kind(precision: Precision) -> PayloadKind {
        match precision {
            Precision::Fp16 => PayloadKind::Fp16,
            Precision::Int(b) | Precision::IntComp(b) => PayloadKind::Quant(b),
        }
    }

    /// Wire bytes of an expert's base payload at `precision`.
    fn base_bytes(&self, precision: Precision) -> usize {
        match precision {
            Precision::Fp16 => self.model.manifest.transfer.fp16_expert_bytes,
            Precision::Int(b) | Precision::IntComp(b) => self.model.manifest.q_expert_bytes(b),
        }
    }

    /// Fetch (or hit) the base payload; returns (tensors, ready time).
    /// A cache entry whose transfer is still in flight (a prefetch, or a
    /// demand fetch another exec issued) is *joined*: no second transfer,
    /// but the requester inherits the in-flight completion time.
    fn acquire_base(
        &mut self,
        layer: usize,
        expert: usize,
        precision: Precision,
        ready: VTime,
    ) -> Result<(Arc<Vec<Tensor>>, VTime)> {
        let key = PayloadKey { layer, expert, kind: Self::payload_kind(precision) };
        if let Some(hit) = self.cache.get_at(&key, ready) {
            // First use of a speculative entry consumes its one-shot flag,
            // so credit coverage regardless of prefill/decode — the
            // prefetch saved a real link fetch either way.
            if hit.first_spec_use {
                self.prefetch.covered += 1;
            }
            return Ok((hit.payload, ready.max(hit.ready_at)));
        }
        let lits = Arc::new(self.model.payload_base(layer, expert, precision, &self.method())?);
        let bytes = self.base_bytes(precision);
        let done = self
            .pcie
            .transfer(ready, bytes, TransferClass::ExpertWeights);
        if !self.in_prefill {
            self.prefetch.demand_fetches += 1;
        }
        self.cache.insert_ready(key, Arc::clone(&lits), bytes, done);
        Ok((lits, done))
    }

    /// Fetch (or hit) the compensator payload for `bits` (never
    /// speculated: compensators are tiny and token-dependent).
    fn acquire_comp(
        &mut self,
        layer: usize,
        expert: usize,
        bits: u8,
        ready: VTime,
    ) -> Result<(Arc<Vec<Tensor>>, VTime)> {
        let key = PayloadKey { layer, expert, kind: PayloadKind::Comp(bits) };
        if let Some(hit) = self.cache.get_at(&key, ready) {
            return Ok((hit.payload, ready.max(hit.ready_at)));
        }
        let tag = self.policy_cfg.comp_tag.clone();
        let lits = Arc::new(self.model.payload_comp(layer, expert, bits, &tag)?);
        let bytes = self.model.manifest.comp_bytes(&tag, bits, layer, expert);
        let done = self.pcie.transfer(ready, bytes, TransferClass::Compensator);
        self.cache.insert_ready(key, Arc::clone(&lits), bytes, done);
        Ok((lits, done))
    }

    fn plan_layer(&self, probs: &[f32], active: &[bool], layer: usize) -> LayerPlan {
        let m = &self.model.manifest.model;
        let cache = &self.cache;
        let probe = move |e: usize| {
            cache.contains(&PayloadKey { layer, expert: e, kind: PayloadKind::Fp16 })
        };
        let ctx = PlanCtx {
            probs,
            n_tokens: active.len(),
            n_experts: m.n_experts,
            top_k: m.top_k,
            active,
            ndp: self.ndp.is_some(),
            fp16_cached: &probe,
            predicted: self.predicted_scores.get(&layer).map(|v| v.as_slice()),
            precisions: self.alloc.as_ref().map(|a| a.layer(layer)),
        };
        self.policy.plan(&ctx)
    }

    /// Feed one layer's routing into the precision allocator's demand EWMA
    /// (prefill and decode both count — prompt routing is the cheapest
    /// warm-up signal; DESIGN.md §10).
    fn observe_alloc(&mut self, layer: usize, probs: &[f32], active: &[bool]) {
        let m = &self.model.manifest.model;
        let (n_experts, top_k, step) = (m.n_experts, m.top_k, self.decode_steps);
        if let Some(a) = self.alloc.as_mut() {
            a.observe(&LayerObservation { step, layer, n_experts, top_k, probs, active });
        }
    }

    /// Execute one layer's MoE (plan → transfers → experts → combine).
    /// Returns the MoE output accumulated on the host.
    fn run_moe_layer(
        &mut self,
        layer: usize,
        xn: &Tensor,
        plan: &LayerPlan,
        active: &[bool],
        prefill: bool,
        router_done: VTime,
    ) -> Result<Vec<f32>> {
        let m = self.model.manifest.model.clone();
        let n_rows = if prefill { m.t_prefill } else { m.b_max };
        let d = m.d_model;
        let mut moe = vec![0f32; n_rows * d];
        let mut ndp_barrier = router_done;
        self.in_prefill = prefill;

        for exec in &plan.execs {
            let n_tok = exec.tokens.len();
            match exec.location {
                Location::Gpu => {
                    let (base, t_base) =
                        self.acquire_base(layer, exec.expert, exec.precision, router_done)?;
                    let (comp, ready) = match exec.precision {
                        Precision::IntComp(bits) => {
                            let (c, t_comp) =
                                self.acquire_comp(layer, exec.expert, bits, router_done)?;
                            (Some(c), t_base.max(t_comp))
                        }
                        _ => (None, t_base),
                    };
                    let avg_rank = if comp.is_some() {
                        self.avg_ranks[layer][exec.expert]
                    } else {
                        0.0
                    };
                    let op = self.cost.expert_gpu(n_tok, exec.precision, avg_rank);
                    let gpu_free = self.gpu.free_at();
                    let (start, _) = self.gpu.acquire(ready, op.seconds);
                    if !prefill {
                        // Decode critical-path stall: how long this exec's
                        // start was pushed past compute availability by
                        // waiting on weight/compensator transfers — the
                        // quantity prefetching exists to shrink (§8).
                        self.breakdown.transfer_stall_s +=
                            (start - gpu_free.max(router_done)).max(0.0);
                    }
                    self.breakdown.expert_compute_s += op.seconds;
                    let refs: Vec<&Tensor> = match &comp {
                        Some(c) => base.iter().chain(c.iter()).collect(),
                        None => base.iter().collect(),
                    };
                    let y = self.model.run_expert(exec.precision, prefill, xn, &refs)?;
                    combine::accumulate(&mut moe, &y.y, exec, d);
                }
                Location::Ndp => {
                    // Activations out, near-data execute, activations back.
                    let act = 2 * n_tok * d; // fp16 per direction
                    let link = self.ndp_link.as_mut().expect("ndp exec without ndp link");
                    let t_in = link.transfer(router_done, act, TransferClass::Activations);
                    let dev = self.ndp.as_mut().expect("ndp exec without device");
                    let op = self.cost.expert_ndp(
                        n_tok,
                        exec.precision,
                        &dev.cfg.clone(),
                    );
                    let t_done = dev.execute_expert(&self.cost, t_in, n_tok, exec.precision);
                    self.breakdown.ndp_compute_s += op.seconds;
                    let link = self.ndp_link.as_mut().unwrap();
                    let t_back = link.transfer(t_done, act, TransferClass::Activations);
                    ndp_barrier = ndp_barrier.max(t_back);
                    // Numerics: same stage executed locally (weights are
                    // resident near-data; no PCIe charge).
                    let lits =
                        self.model
                            .payload_base(layer, exec.expert, exec.precision, &self.method())?;
                    let refs: Vec<&Tensor> = lits.iter().collect();
                    let y = self.model.run_expert(exec.precision, prefill, xn, &refs)?;
                    combine::accumulate(&mut moe, &y.y, exec, d);
                }
            }
        }

        // Shared experts (DeepSeek-style): GPU-resident, fp16, every token.
        for s in 0..m.n_shared {
            let n_live = active.iter().filter(|&&a| a).count();
            let op = self.cost.expert_gpu(n_live, Precision::Fp16, 0.0);
            self.gpu.acquire(router_done, op.seconds);
            self.breakdown.expert_compute_s += op.seconds;
            let y = self.model.run_shared_expert(layer, s, prefill, xn)?;
            combine::accumulate_all(&mut moe, &y.y, active, d);
        }

        self.gpu.sync_to(ndp_barrier);
        Ok(moe)
    }

    /// Crate-visible planning seam for the teacher-forced scorer (same
    /// path as serving; was the `plan_layer_pub` test hook).
    pub(crate) fn plan_layer_for_scoring(
        &self,
        probs: &[f32],
        active: &[bool],
        layer: usize,
    ) -> LayerPlan {
        self.plan_layer(probs, active, layer)
    }

    /// Crate-visible MoE execution seam for the scorer (virtual time still
    /// advances, but scoring runs use a dedicated engine instance; was the
    /// `run_moe_layer_pub` test hook).
    pub(crate) fn run_moe_layer_for_scoring(
        &mut self,
        layer: usize,
        xn: &Tensor,
        plan: &LayerPlan,
        active: &[bool],
        prefill: bool,
    ) -> Result<Vec<f32>> {
        let t = self.clock.now();
        self.run_moe_layer(layer, xn, plan, active, prefill, t)
    }

    /// One decode step over all active slots.
    pub fn decode_step(&mut self) -> Result<()> {
        let m = self.model.manifest.model.clone();
        let (tokens, pos) = self.state.decode_inputs();
        let active = self.state.active_rows();
        let n_active = active.iter().filter(|&&a| a).count();
        if n_active == 0 {
            return Ok(());
        }
        let step_t0 = self.clock.now();
        self.prefetch.begin_step();
        // Decode-step boundary: refresh the per-expert precision plan from
        // the routing demand accumulated so far (DESIGN.md §10).
        if let Some(a) = self.alloc.as_mut() {
            a.replan();
        }

        let mut x = self.model.embed(&tokens, false)?;
        let op = self.cost.embed(n_active);
        self.gpu.acquire(step_t0, op.seconds);

        let ctx_total: usize = pos.iter().map(|&p| p as usize + 1).sum();
        for layer in 0..m.n_layers {
            let (x2, kc, vc) = self.model.attn_decode(
                layer,
                &x,
                &self.state.kv[layer].k,
                &self.state.kv[layer].v,
                &pos,
            )?;
            self.state.kv[layer] = LayerKv { k: kc, v: vc };
            let (xn, probs) = self.model.router(layer, &x2, false)?;
            let op = self.cost.attn_router(n_active, ctx_total);
            let (_, router_done) = self.gpu.acquire(self.clock.now(), op.seconds);
            self.breakdown.attn_router_s += op.seconds;

            self.observe_alloc(layer, &probs, &active);
            let plan = self.plan_layer(&probs, &active, layer);
            debug_assert!(combine::plan_is_partition(&plan, m.b_max, m.top_k, &active));

            if let Some(t) = self.trace.as_mut() {
                if active[0] {
                    let row = &probs[..m.n_experts];
                    let sel = crate::policies::plan::topk_renorm(row, m.top_k)
                        .into_iter()
                        .map(|(e, w, _)| (e, w))
                        .collect();
                    t.push(self.decode_steps as usize, layer, sel);
                }
            }

            let moe = self.run_moe_layer(layer, &xn, &plan, &active, false, router_done)?;
            let mut xh = x2.to_f32_vec()?;
            for (a, b) in xh.iter_mut().zip(&moe) {
                *a += b;
            }
            x = self.model.make_x(m.b_max, &xh)?;

            // Speculate on upcoming layers now that this layer's demand
            // transfers are queued (FIFO link ⇒ speculation yields to
            // demand) and the updated hidden state exists for the gate
            // lookahead (DESIGN.md §8).
            self.issue_prefetches(layer, &x, &probs, &active, router_done)?;
        }

        let logits = self.model.head(&x)?;
        let op = self.cost.head(n_active);
        self.gpu.acquire(self.clock.now(), op.seconds);
        self.breakdown.head_s += op.seconds;

        self.end_step();
        let now = self.clock.now();

        // Greedy sampling + completion handling.
        for slot in 0..m.b_max {
            if let Some(seq) = self.state.slots[slot].as_mut() {
                let row = &logits[slot * m.vocab..(slot + 1) * m.vocab];
                let next = argmax(row) as i32;
                seq.tokens.push(next);
                self.total_generated += 1;
                let done = seq.done();
                self.emitted.push(EmittedToken {
                    request_id: seq.request_id,
                    token: next,
                    index: seq.generated() - 1,
                    at: now,
                    last: done,
                });
                if done {
                    let seq = self.state.release(slot).unwrap();
                    self.records.push(RequestRecord {
                        id: seq.request_id,
                        prompt_len: seq.prompt_len,
                        generated: seq.generated(),
                        arrival: seq.arrival,
                        first_token_at: seq.first_token_at.unwrap_or(now),
                        finished_at: now,
                    });
                }
            }
        }
        self.decode_steps += 1;
        Ok(())
    }

    /// Prefill one request into `slot` (its own virtual step).
    pub fn prefill(&mut self, slot: usize, req: &Request) -> Result<()> {
        let m = self.model.manifest.model.clone();
        let plen = req.prompt.len().min(m.t_prefill);
        self.state.admit(slot, req, self.clock.now());
        let step_t0 = self.clock.now();

        let mut toks = req.prompt[..plen].to_vec();
        toks.resize(m.t_prefill, 0);
        let mut x = self.model.embed(&toks, true)?;
        self.gpu.acquire(step_t0, self.cost.embed(plen).seconds);

        let active: Vec<bool> = (0..m.t_prefill).map(|i| i < plen).collect();
        let ctx_total = plen * (plen + 1) / 2;
        for layer in 0..m.n_layers {
            let (x2, kc, vc) = self.model.attn_prefill(layer, &x)?;
            self.state.install_prefill(slot, layer, &kc, &vc)?;
            let (xn, probs) = self.model.router(layer, &x2, true)?;
            let op = self.cost.attn_router(plen, ctx_total);
            let (_, router_done) = self.gpu.acquire(self.clock.now(), op.seconds);
            self.breakdown.attn_router_s += op.seconds;

            self.observe_alloc(layer, &probs, &active);
            let plan = self.plan_layer(&probs, &active, layer);
            let moe = self.run_moe_layer(layer, &xn, &plan, &active, true, router_done)?;
            let mut xh = x2.to_f32_vec()?;
            for (a, b) in xh.iter_mut().zip(&moe) {
                *a += b;
            }
            x = self.model.make_x(m.t_prefill, &xh)?;
        }

        // First generated token from the last prompt position's hidden.
        let xh = x.to_f32_vec()?;
        let mut batch_x = vec![0f32; m.b_max * m.d_model];
        batch_x[slot * m.d_model..(slot + 1) * m.d_model]
            .copy_from_slice(&xh[(plen - 1) * m.d_model..plen * m.d_model]);
        let x_lit = self.model.make_x(m.b_max, &batch_x)?;
        let logits = self.model.head(&x_lit)?;
        self.gpu.acquire(self.clock.now(), self.cost.head(1).seconds);

        self.end_step();
        let now = self.clock.now();
        let seq = self.state.slots[slot].as_mut().unwrap();
        let next = argmax(&logits[slot * m.vocab..(slot + 1) * m.vocab]) as i32;
        seq.tokens.push(next);
        seq.first_token_at = Some(now);
        self.emitted.push(EmittedToken {
            request_id: seq.request_id,
            token: next,
            index: 0,
            at: now,
            last: seq.done(),
        });
        self.total_generated += 1;
        self.prefills += 1;
        Ok(())
    }

    /// Observe layer `layer`'s routing and issue budgeted speculative
    /// transfers for the layers the predictor expects next (DESIGN.md §8).
    /// `x_next` is the layer's *output* hidden (the residual stream the
    /// gate lookahead scores); `router_done` is the earliest data-valid
    /// time for speculation this layer.
    fn issue_prefetches(
        &mut self,
        layer: usize,
        x_next: &Tensor,
        probs: &[f32],
        active: &[bool],
        router_done: VTime,
    ) -> Result<()> {
        let Some(mut pred) = self.predictor.take() else {
            return Ok(());
        };
        let out = self.issue_with(pred.as_mut(), layer, x_next, probs, active, router_done);
        self.predictor = Some(pred);
        out
    }

    fn issue_with(
        &mut self,
        pred: &mut dyn ExpertPredictor,
        layer: usize,
        x_next: &Tensor,
        probs: &[f32],
        active: &[bool],
        router_done: VTime,
    ) -> Result<()> {
        let m = self.model.manifest.model.clone();
        pred.observe(&LayerObservation {
            step: self.decode_steps,
            layer,
            n_experts: m.n_experts,
            top_k: m.top_k,
            probs,
            active,
        });
        // A predictor exists (the caller took it out of `self.predictor`)
        // but the numeric knobs may still forbid issuing.
        if !self.prefetch_cfg.issuable() {
            return Ok(());
        }
        // Speculate the policy's *bulk* payload only: compensators are
        // token-dependent and tiny, so they stay on demand.
        let prec = self.policy.bulk_precision();
        let kind = Self::payload_kind(prec);
        let bytes_each = self.base_bytes(prec);
        let n_active = active.iter().filter(|&&a| a).count();
        let cap = (n_active * m.top_k).clamp(m.top_k, m.n_experts);

        for depth in 1..=self.prefetch_cfg.lookahead.min(m.n_layers) {
            // Budget gone: don't burn router stages on predictions we
            // could never issue (the scores are advisory only).
            if self.prefetch.budget_left() < bytes_each {
                break;
            }
            // Past the last layer the lookahead wraps to the next decode
            // step's early layers.
            let lf = layer + depth;
            let (t_layer, t_step) = if lf < m.n_layers {
                (lf, self.decode_steps)
            } else {
                (lf - m.n_layers, self.decode_steps + 1)
            };
            // The gate lookahead scores the target layer's router on the
            // current residual stream — host-side math on an idle-tiny
            // GEMV (d × E ≪ one attention), so no virtual-time charge.
            let la_probs: Option<Vec<f32>> = if pred.wants_lookahead() {
                Some(self.model.router(t_layer, x_next, false)?.1)
            } else {
                None
            };
            let ctx = PredictCtx {
                step: t_step,
                layer: t_layer,
                n_experts: m.n_experts,
                top_k: m.top_k,
                active,
                lookahead_probs: la_probs.as_deref(),
            };
            let ranked = pred.predict(&ctx);
            let mut dense = vec![0f64; m.n_experts];
            for p in &ranked {
                dense[p.expert] = p.score;
            }
            self.predicted_scores.insert(t_layer, dense);

            for p in ranked.into_iter().take(cap) {
                let key = PayloadKey { layer: t_layer, expert: p.expert, kind };
                // Dedup against resident payloads and in-flight fetches.
                if self.cache.contains(&key) {
                    continue;
                }
                if !self.prefetch.try_spend(bytes_each) {
                    return Ok(()); // step budget exhausted
                }
                let lits =
                    Arc::new(self.model.payload_base(t_layer, p.expert, prec, &self.method())?);
                let done =
                    self.pcie
                        .transfer(router_done, bytes_each, TransferClass::Speculative);
                self.cache.insert_speculative(key, lits, bytes_each, done);
                self.prefetch.issued += 1;
            }
        }
        Ok(())
    }

    fn end_step(&mut self) {
        let mut resources: Vec<&mut Resource> = vec![&mut self.gpu, &mut self.pcie.resource];
        if let Some(l) = self.ndp_link.as_mut() {
            resources.push(&mut l.resource);
        }
        if let Some(n) = self.ndp.as_mut() {
            resources.push(&mut n.compute);
        }
        self.clock.end_step(&mut resources);
    }

    pub fn now(&self) -> VTime {
        self.clock.now()
    }

    pub fn report(&self) -> Report {
        let mut bytes = std::collections::HashMap::new();
        let mut breakdown = self.breakdown.clone();
        let logs = [
            Some(&self.pcie.log),
            self.ndp_link.as_ref().map(|l| &l.log),
        ];
        for log in logs.into_iter().flatten() {
            bytes
                .entry("expert_weights".to_string())
                .and_modify(|b| *b += log.bytes_of(TransferClass::ExpertWeights))
                .or_insert(log.bytes_of(TransferClass::ExpertWeights));
            bytes
                .entry("compensator".to_string())
                .and_modify(|b| *b += log.bytes_of(TransferClass::Compensator))
                .or_insert(log.bytes_of(TransferClass::Compensator));
            bytes
                .entry("activations".to_string())
                .and_modify(|b| *b += log.bytes_of(TransferClass::Activations))
                .or_insert(log.bytes_of(TransferClass::Activations));
            bytes
                .entry("speculative_weights".to_string())
                .and_modify(|b| *b += log.bytes_of(TransferClass::Speculative))
                .or_insert(log.bytes_of(TransferClass::Speculative));
        }
        let pcie_busy = |class: TransferClass| -> f64 {
            self.pcie
                .log
                .events
                .iter()
                .filter(|e| e.class == class)
                .map(|e| e.end - e.start)
                .sum()
        };
        breakdown.transfer_weights_s = pcie_busy(TransferClass::ExpertWeights);
        breakdown.transfer_comp_s = pcie_busy(TransferClass::Compensator);
        breakdown.transfer_spec_s = pcie_busy(TransferClass::Speculative);
        breakdown.transfer_act_s = self
            .ndp_link
            .as_ref()
            .map(|l| l.log.busy_seconds())
            .unwrap_or(0.0);

        Report {
            policy: self.policy.name().to_string(),
            model: self.model.manifest.model.name.clone(),
            n_requests: self.records.len(),
            total_generated: self.total_generated,
            virtual_seconds: self.clock.now(),
            wall_seconds: self.started.elapsed().as_secs_f64(),
            decode_steps: self.decode_steps,
            prefills: self.prefills,
            breakdown,
            bytes,
            cache_hit_rate: self.cache.hit_rate(),
            requests: self.records.clone(),
            backend_execs: self.model.backend().exec_count(),
            prefetch: PrefetchReport {
                predictor: self
                    .predictor
                    .as_ref()
                    .map(|p| p.name())
                    .unwrap_or("off")
                    .to_string(),
                issued: self.prefetch.issued,
                covered: self.prefetch.covered,
                demand_fetches: self.prefetch.demand_fetches,
                speculative_bytes: self.pcie.log.bytes_of(TransferClass::Speculative),
                wasted_bytes: self.cache.wasted_speculative_bytes
                    + self.cache.resident_unused_speculative_bytes(),
            },
            alloc: self.alloc.as_ref().map(|a| a.report()),
        }
    }
}

/// Greedy sampling argmax, first index on ties; `total_cmp` keeps it
/// panic-free (and deterministic) even on NaN-poisoned logits.
pub(crate) fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}

//! Sequence slots and batched KV-cache state.
//!
//! The decode batch has `b_max` fixed slots.  Each active slot owns a
//! sequence (prompt + generated tokens) and one row of every layer's
//! batched KV-cache literal.  Freed slots are reused without zeroing — the
//! decode attention kernel masks reads beyond each slot's length
//! (`kernels/attention.py`), so stale rows are harmless by construction.

use anyhow::Result;

use crate::backend::Tensor;
use crate::runtime::StagedModel;
use crate::sim::clock::VTime;
use crate::workload::Request;

/// One in-flight request bound to a slot.
#[derive(Debug, Clone)]
pub struct ActiveSeq {
    pub request_id: u64,
    /// Prompt + generated tokens.
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub arrival: VTime,
    pub first_token_at: Option<VTime>,
}

impl ActiveSeq {
    pub fn generated(&self) -> usize {
        self.tokens.len() - self.prompt_len
    }

    pub fn done(&self) -> bool {
        self.generated() >= self.max_new_tokens
    }

    /// Write position of the *next* decode step's KV entry.
    pub fn next_pos(&self) -> i32 {
        (self.tokens.len() - 1) as i32
    }
}

/// Batched KV caches for one layer.
pub struct LayerKv {
    pub k: Tensor,
    pub v: Tensor,
}

pub struct BatchState {
    pub slots: Vec<Option<ActiveSeq>>,
    pub kv: Vec<LayerKv>,
    b_max: usize,
    n_heads: usize,
    s_max: usize,
    d_head: usize,
}

impl BatchState {
    pub fn new(model: &StagedModel) -> Result<Self> {
        let m = &model.manifest.model;
        let mut kv = Vec::with_capacity(m.n_layers);
        for _ in 0..m.n_layers {
            let (k, v) = model.empty_caches()?;
            kv.push(LayerKv { k, v });
        }
        Ok(BatchState {
            slots: (0..m.b_max).map(|_| None).collect(),
            kv,
            b_max: m.b_max,
            n_heads: m.n_heads,
            s_max: m.s_max,
            d_head: m.d_head(),
        })
    }

    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    pub fn active_rows(&self) -> Vec<bool> {
        self.slots.iter().map(|s| s.is_some()).collect()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn admit(&mut self, slot: usize, req: &Request, now: VTime) {
        debug_assert!(self.slots[slot].is_none());
        self.slots[slot] = Some(ActiveSeq {
            request_id: req.id,
            tokens: req.prompt.clone(),
            prompt_len: req.prompt.len(),
            max_new_tokens: req.max_new_tokens,
            arrival: req.arrival.max(now),
            first_token_at: None,
        });
    }

    pub fn release(&mut self, slot: usize) -> Option<ActiveSeq> {
        self.slots[slot].take()
    }

    /// Per-slot decode inputs: (last token, write position).  Inactive
    /// slots get (0, 0) — the attention kernel clamps lengths to ≥1 so the
    /// padded rows produce finite garbage that the combine step ignores.
    pub fn decode_inputs(&self) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = vec![0i32; self.b_max];
        let mut pos = vec![0i32; self.b_max];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(seq) = s {
                tokens[i] = *seq.tokens.last().unwrap();
                pos[i] = seq.next_pos();
            }
        }
        (tokens, pos)
    }

    /// Install a freshly prefilled slot cache (H, S, dh) into the batched
    /// (B, H, S, dh) tensors for `slot`.  Host-side patch: runs once per
    /// request, not per token.
    pub fn install_prefill(
        &mut self,
        slot: usize,
        layer: usize,
        k_slot: &Tensor,
        v_slot: &Tensor,
    ) -> Result<()> {
        let row = self.n_heads * self.s_max * self.d_head;
        let lk = &mut self.kv[layer];
        for (batched, incoming) in [(&mut lk.k, k_slot), (&mut lk.v, v_slot)] {
            let host = batched.as_f32_mut()?;
            host[slot * row..(slot + 1) * row].copy_from_slice(incoming.as_f32()?);
        }
        Ok(())
    }
}

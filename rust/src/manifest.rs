//! Artifact manifest parsing and the BEAMW weight store.
//!
//! `manifest.json` (written by `python/compile/aot.py`) indexes everything
//! the coordinator needs: model dims, HLO stage files, quantization layout,
//! compensator rank tables and the transfer-byte tables the link simulator
//! charges.  `weights.beamw` / `eval.beamw` are BEAMW containers (see
//! `python/compile/beamw.py` for the format spec — magic `BEAMW001`,
//! u64 header length, JSON tensor table, raw little-endian blob).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::ModelDims;
use crate::jsonx::Value;

/// One HLO stage entry in the manifest.
#[derive(Debug, Clone)]
pub struct StageEntry {
    pub file: String,
    pub n_inputs: usize,
}

#[derive(Debug, Clone)]
pub struct QuantInfo {
    pub methods: Vec<String>,
    pub bits: Vec<u8>,
    pub comp_bits: Vec<u8>,
    /// bits -> kernel container bits ("3" rides in 4-bit containers).
    pub container_bits: HashMap<u8, u8>,
    pub v_group: usize,
}

#[derive(Debug, Clone)]
pub struct RankTableEntry {
    /// True compensator rank per matrix, ordered like `mat_keys`.
    pub ranks: Vec<usize>,
    pub r_avg: usize,
}

#[derive(Debug, Clone)]
pub struct TransferTables {
    /// Bytes to move one FP16 expert (w1+w2+w3) across a link.
    pub fp16_expert_bytes: usize,
    /// bits -> bytes for one packed quantized expert incl. fp16 metadata.
    pub q_expert_bytes: HashMap<u8, usize>,
    /// tag -> bits -> [layer][expert] compensator bytes (true ranks).
    pub comp_bytes: HashMap<String, HashMap<u8, Vec<Vec<usize>>>>,
}

/// Parsed `artifacts/<model>/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelDims,
    pub stages: HashMap<String, StageEntry>,
    pub quant: QuantInfo,
    pub rank_table: HashMap<String, RankTableEntry>,
    pub mat_keys: Vec<String>,
    pub transfer: TransferTables,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(model_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = model_dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let raw = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Value::parse(&raw).context("parsing manifest.json")?;

        let m = v.get("model")?;
        let model = ModelDims {
            name: m.get("name")?.str()?.to_string(),
            vocab: m.get("vocab")?.usize()?,
            d_model: m.get("d_model")?.usize()?,
            d_ff: m.get("d_ff")?.usize()?,
            n_layers: m.get("n_layers")?.usize()?,
            n_heads: m.get("n_heads")?.usize()?,
            n_experts: m.get("n_experts")?.usize()?,
            top_k: m.get("top_k")?.usize()?,
            n_shared: m.get("n_shared")?.usize()?,
            s_max: m.get("s_max")?.usize()?,
            t_prefill: m.get("t_prefill")?.usize()?,
            b_max: m.get("b_max")?.usize()?,
            group_size: m.get("group_size")?.usize()?,
            rank_pad: m.get("rank_pad")?.usize()?,
            r_avg: m.get("r_avg")?.usize()?,
            top_n: m.get("top_n")?.usize()?,
        };

        let mut stages = HashMap::new();
        for (name, entry) in v.get("stages")?.obj()? {
            stages.insert(
                name.clone(),
                StageEntry {
                    file: entry.get("file")?.str()?.to_string(),
                    n_inputs: entry
                        .opt("inputs")
                        .map(|i| i.arr().map(|a| a.len()).unwrap_or(0))
                        .unwrap_or(0),
                },
            );
        }

        let q = v.get("quant")?;
        let quant = QuantInfo {
            methods: q
                .get("methods")?
                .arr()?
                .iter()
                .map(|s| s.str().map(str::to_string))
                .collect::<Result<_>>()?,
            bits: q.get("bits")?.usize_vec()?.iter().map(|&b| b as u8).collect(),
            comp_bits: q.get("comp_bits")?.usize_vec()?.iter().map(|&b| b as u8).collect(),
            container_bits: q
                .get("container_bits")?
                .obj()?
                .iter()
                .map(|(k, val)| Ok((k.parse::<u8>()?, val.usize()? as u8)))
                .collect::<Result<_>>()?,
            v_group: q.get("v_group")?.usize()?,
        };

        let mut rank_table = HashMap::new();
        for (tag, entry) in v.get("rank_table")?.obj()? {
            rank_table.insert(
                tag.clone(),
                RankTableEntry {
                    ranks: entry.get("ranks")?.usize_vec()?,
                    r_avg: entry.get("r_avg")?.usize()?,
                },
            );
        }

        let mat_keys = v
            .get("mat_keys")?
            .arr()?
            .iter()
            .map(|s| s.str().map(str::to_string))
            .collect::<Result<_>>()?;

        let t = v.get("transfer")?;
        let mut q_expert_bytes = HashMap::new();
        for (bits, val) in t.get("q_expert_bytes")?.obj()? {
            q_expert_bytes.insert(bits.parse::<u8>()?, val.usize()?);
        }
        let mut comp_bytes = HashMap::new();
        for (tag, by_bits) in t.get("comp_bytes")?.obj()? {
            let mut inner = HashMap::new();
            for (bits, table) in by_bits.obj()? {
                let rows: Vec<Vec<usize>> = table
                    .arr()?
                    .iter()
                    .map(|r| r.usize_vec())
                    .collect::<Result<_>>()?;
                inner.insert(bits.parse::<u8>()?, rows);
            }
            comp_bytes.insert(tag.clone(), inner);
        }
        let transfer = TransferTables {
            fp16_expert_bytes: t.get("fp16_expert_bytes")?.usize()?,
            q_expert_bytes,
            comp_bytes,
        };

        let manifest = Manifest { model, stages, quant, rank_table, mat_keys, transfer, dir };
        manifest.validate().context("validating manifest.json")?;
        Ok(manifest)
    }

    /// Reject impossible model-dims/bit-width combinations up front, with
    /// enough context to point at the bad knob — the pack-chunk rules used
    /// to surface as an `assert!` panic deep inside byte accounting.
    pub fn validate(&self) -> Result<()> {
        let m = &self.model;
        let g = m.group_size;
        if g == 0 || m.d_model % g != 0 || m.d_ff % g != 0 {
            bail!(
                "model `{}`: group_size {g} must divide d_model {} and d_ff {}",
                m.name,
                m.d_model,
                m.d_ff
            );
        }
        for &bits in &self.quant.bits {
            let (cpc, _) = crate::quant::formats::pack_chunk(bits)
                .with_context(|| format!("model `{}` declares {bits}-bit payloads", m.name))?;
            if (m.d_model * m.d_ff) % cpc != 0 {
                bail!(
                    "model `{}`: d_model×d_ff = {} is not a multiple of the {bits}-bit \
                     pack chunk ({cpc} codes) — these dims cannot ship {bits}-bit experts",
                    m.name,
                    m.d_model * m.d_ff
                );
            }
        }
        Ok(())
    }

    pub fn stage_path(&self, name: &str) -> Result<PathBuf> {
        let entry = self
            .stages
            .get(name)
            .with_context(|| format!("stage `{name}` not in manifest"))?;
        Ok(self.dir.join(&entry.file))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join("weights.beamw")
    }

    pub fn eval_path(&self) -> PathBuf {
        self.dir.join("eval.beamw")
    }

    /// Container bit-width the kernels consume for a given precision.
    pub fn container_bits(&self, bits: u8) -> u8 {
        self.quant
            .container_bits
            .get(&bits)
            .copied()
            .unwrap_or(if bits == 3 { 4 } else { bits })
    }

    /// Bytes on the wire for one expert at `bits` (packed codes + metadata).
    pub fn q_expert_bytes(&self, bits: u8) -> usize {
        self.transfer
            .q_expert_bytes
            .get(&bits)
            .copied()
            .unwrap_or_else(|| self.model.expert_params() * bits as usize / 8)
    }

    /// Total `tag` compensator bytes at `bits` across every (layer,
    /// expert) — the "compensate everything" headroom the adaptive
    /// sweep's budget points are denominated in (DESIGN.md §10).
    pub fn comp_bytes_total(&self, tag: &str, bits: u8) -> usize {
        let (nl, ne) = (self.model.n_layers, self.model.n_experts);
        let mut total = 0;
        for layer in 0..nl {
            for expert in 0..ne {
                total += self.comp_bytes(tag, bits, layer, expert);
            }
        }
        total
    }

    /// Compensator bytes for (tag, bits, layer, expert); 0 when absent.
    pub fn comp_bytes(&self, tag: &str, bits: u8, layer: usize, expert: usize) -> usize {
        self.transfer
            .comp_bytes
            .get(tag)
            .and_then(|m| m.get(&bits))
            .and_then(|t| t.get(layer))
            .and_then(|r| r.get(expert))
            .copied()
            .unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// BEAMW reader
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U8,
    I8,
}

impl Dtype {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "u8" => Dtype::U8,
            "i8" => Dtype::I8,
            other => bail!("unknown BEAMW dtype `{other}`"),
        })
    }

    pub fn size(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 | Dtype::I8 => 1,
        }
    }
}

/// A tensor view into the shared BEAMW blob (zero-copy until literalized).
#[derive(Debug, Clone)]
pub struct TensorView {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
    blob: Arc<Vec<u8>>,
    offset: usize,
    nbytes: usize,
}

impl TensorView {
    /// Build a standalone view over owned bytes (synthetic models / tests —
    /// the file-free twin of the BEAMW reader below).
    pub fn from_bytes(dtype: Dtype, shape: Vec<usize>, bytes: Vec<u8>) -> Result<Self> {
        let expect = shape.iter().product::<usize>() * dtype.size();
        if expect != bytes.len() {
            bail!("tensor view: shape {shape:?} wants {expect} bytes, got {}", bytes.len());
        }
        let nbytes = bytes.len();
        Ok(TensorView { dtype, shape, blob: Arc::new(bytes), offset: 0, nbytes })
    }

    pub fn from_f32(shape: Vec<usize>, data: &[f32]) -> Result<Self> {
        let bytes = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self::from_bytes(Dtype::F32, shape, bytes)
    }

    pub fn from_i32(shape: Vec<usize>, data: &[i32]) -> Result<Self> {
        let bytes = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        Self::from_bytes(Dtype::I32, shape, bytes)
    }

    pub fn from_u8(shape: Vec<usize>, data: &[u8]) -> Result<Self> {
        Self::from_bytes(Dtype::U8, shape, data.to_vec())
    }

    pub fn bytes(&self) -> &[u8] {
        &self.blob[self.offset..self.offset + self.nbytes]
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("tensor is {:?}, not f32", self.dtype);
        }
        Ok(self
            .bytes()
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 {
            bail!("tensor is {:?}, not i32", self.dtype);
        }
        Ok(self
            .bytes()
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        if !matches!(self.dtype, Dtype::U8 | Dtype::I8) {
            bail!("tensor is {:?}, not u8/i8", self.dtype);
        }
        Ok(self.bytes())
    }
}

/// In-memory BEAMW container: one blob + a name index.
///
/// In the offloading model this is "host memory": holding the store resident
/// in RAM is exactly what Mixtral-Offloading does with expert weights, and
/// literalization on demand is the host→device copy the link simulator prices.
pub struct WeightStore {
    tensors: HashMap<String, TensorView>,
}

impl WeightStore {
    /// Empty in-memory store; populate with [`WeightStore::insert`]
    /// (synthetic models / tests).
    pub fn new() -> Self {
        WeightStore { tensors: HashMap::new() }
    }

    pub fn insert(&mut self, name: impl Into<String>, view: TensorView) {
        self.tensors.insert(name.into(), view);
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let raw = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        if raw.len() < 16 || &raw[..8] != b"BEAMW001" {
            bail!("bad BEAMW magic in {}", path.as_ref().display());
        }
        let hlen = u64::from_le_bytes(raw[8..16].try_into().unwrap()) as usize;
        let header = Value::parse(std::str::from_utf8(&raw[16..16 + hlen])?)
            .context("BEAMW header")?;
        let blob = Arc::new(raw[16 + hlen..].to_vec());
        let entries = header.get("tensors")?.arr()?;
        let mut tensors = HashMap::with_capacity(entries.len());
        for e in entries {
            let name = e.get("name")?.str()?.to_string();
            let dtype = Dtype::parse(e.get("dtype")?.str()?)?;
            let shape = e.get("shape")?.usize_vec()?;
            let offset = e.get("offset")?.usize()?;
            let nbytes = e.get("nbytes")?.usize()?;
            let expect = shape.iter().product::<usize>() * dtype.size();
            if expect != nbytes {
                bail!("tensor {name}: shape/nbytes mismatch");
            }
            if offset + nbytes > blob.len() {
                bail!("tensor {name}: out of blob bounds");
            }
            tensors.insert(
                name,
                TensorView { dtype, shape, blob: Arc::clone(&blob), offset, nbytes },
            );
        }
        Ok(WeightStore { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&TensorView> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor `{name}` not in weight store"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.tensors.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

impl Default for WeightStore {
    fn default() -> Self {
        Self::new()
    }
}

//! `beamctl` — the control-plane client for a running `beamd`
//! (DESIGN.md §14).  Thin wrapper over
//! [`beam_moe::ctl::client::run_cli`]; also reachable as `beam ctl …`.

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    beam_moe::ctl::client::run_cli(&args)
}

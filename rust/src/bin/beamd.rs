//! `beamd` — the live-reconfigurable serving daemon (DESIGN.md §14).
//!
//! Thin wrapper over [`beam_moe::ctl::daemon::run_cli`]; also reachable
//! as `beam daemon …`.  See the README's control-plane quickstart.

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    beam_moe::ctl::daemon::run_cli(&args)
}

//! Bench: paper Fig. 8 + Table 2 ablations (restored-expert count, rank
//! budget, kurtosis vs uniform allocation, position-specific restore).
//!
//! `cargo bench --bench fig8_ablation` — scoring-based, so slower than the
//! throughput benches; uses the reduced eval set.

mod common;

use std::path::PathBuf;

use beam_moe::harness::figures::{fig8, tab2, Harness};

fn main() -> anyhow::Result<()> {
    common::header("fig8 + tab2: ablations");
    let mut h = Harness::new(PathBuf::from("artifacts"), Some(PathBuf::from("reports")), false)?;
    h.eval_seqs = 12; // bench-sized subset; `beam figure fig8 --full` for the real run
    fig8(&mut h)?;
    tab2(&mut h)?;
    Ok(())
}

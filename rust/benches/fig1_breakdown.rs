//! Bench: regenerate paper Fig. 1 (time breakdown + roofline).
//!
//! `cargo bench --bench fig1_breakdown` — runs the FP16-offloading serving
//! point and prints the transfer/compute split plus the roofline table,
//! with wall-clock timings of the underlying serve loop.

mod common;

use std::path::PathBuf;
use std::time::Instant;

use beam_moe::harness::figures::{fig1, Harness};

fn main() -> anyhow::Result<()> {
    common::header("fig1: offloaded inference breakdown + roofline");
    let mut h = Harness::new(PathBuf::from("artifacts"), Some(PathBuf::from("reports")), false)?;
    let t0 = Instant::now();
    fig1(&mut h)?;
    println!("[bench] fig1 regenerated in {:.2}s wall", t0.elapsed().as_secs_f64());
    Ok(())
}

//! Shared bench scaffolding (no criterion in the offline vendor set —
//! `harness = false` mains with wall-clock + virtual-clock reporting).

use std::time::Instant;

/// Time a closure `iters` times; report min/mean wall time.
pub fn time<F: FnMut()>(label: &str, iters: usize, mut f: F) {
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    println!("  {label:<44} min {:>10.3} ms | mean {:>10.3} ms | n={iters}", min * 1e3, mean * 1e3);
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

//! Micro-benchmarks of the L3 hot path (the §Perf targets).
//!
//! `cargo bench --bench hotpath` — times the pieces the decode loop is made
//! of: payload literalization (the host-side cost of a cache miss), routing
//! plan construction, MoE combine, cache ops, a full expert stage execution
//! and one end-to-end decode step.  EXPERIMENTS.md §Perf tracks these
//! before/after each optimization.

mod common;

use std::path::PathBuf;
use std::sync::Arc;

use beam_moe::backend::{default_backend, Tensor};
use beam_moe::config::{PolicyConfig, Precision, PrefetchConfig, SystemConfig};
use beam_moe::coordinator::combine;
use beam_moe::coordinator::scheduler::serve;
use beam_moe::coordinator::ServeEngine;
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::offload::cache::{ExpertCache, PayloadKey, PayloadKind};
use beam_moe::offload::prefetch::PrefetchQueue;
use beam_moe::policies::plan::{topk_renorm, ExpertExec, Location, TokenAssign};
use beam_moe::predict::{EwmaPopularity, ExpertPredictor, LayerObservation, PredictCtx};
use beam_moe::runtime::StagedModel;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};

fn main() -> anyhow::Result<()> {
    common::header("hotpath micro-benchmarks (wall-clock)");
    let backend = default_backend()?;
    let model = StagedModel::load(Arc::clone(&backend), Manifest::load("artifacts/mixtral-tiny")?)?;
    let dims = model.manifest.model.clone();

    // 1. Payload literalization (cache-miss host cost).
    common::time("payload_base int2 (9 tensors)", 200, || {
        let _ = model.payload_base(0, 0, Precision::Int(2), "hqq").unwrap();
    });
    common::time("payload_base fp16 (3 tensors)", 200, || {
        let _ = model.payload_base(0, 0, Precision::Fp16, "hqq").unwrap();
    });
    common::time("payload_comp int2 (18 tensors)", 200, || {
        let _ = model.payload_comp(0, 0, 2, "default").unwrap();
    });

    // 2. Routing plan (pure CPU).
    let probs: Vec<f32> = (0..dims.b_max * dims.n_experts)
        .map(|i| ((i * 2654435761usize) % 1000) as f32 / 1000.0)
        .collect();
    common::time("topk_renorm x batch", 10_000, || {
        for r in 0..dims.b_max {
            let row = &probs[r * dims.n_experts..(r + 1) * dims.n_experts];
            let _ = topk_renorm(row, dims.top_k);
        }
    });

    // 3. MoE combine.
    let y = vec![0.5f32; dims.b_max * dims.d_model];
    let exec = ExpertExec {
        expert: 0,
        precision: Precision::Int(2),
        location: Location::Gpu,
        tokens: (0..dims.b_max)
            .map(|row| TokenAssign { row, weight: 0.5, rank: 0 })
            .collect(),
    };
    common::time("combine::accumulate full batch", 10_000, || {
        let mut acc = vec![0f32; dims.b_max * dims.d_model];
        combine::accumulate(&mut acc, &y, &exec, dims.d_model);
    });

    // 4. Cache ops.
    let mut cache = ExpertCache::new(1 << 20);
    common::time("cache insert+get", 10_000, || {
        let key = PayloadKey { layer: 0, expert: 0 };
        cache.insert(key, PayloadKind::Quant(2), Arc::new(Vec::new()), 1024);
        let _ = cache.get(&key, PayloadKind::Quant(2));
    });
    // Eviction-heavy path: the BTreeMap recency index must keep this O(log n).
    let mut small = ExpertCache::new(8 * 1024);
    common::time("cache insert w/ eviction", 10_000, || {
        for e in 0..16 {
            let key = PayloadKey { layer: 0, expert: e };
            small.insert(key, PayloadKind::Quant(2), Arc::new(Vec::new()), 1024);
        }
    });

    // 4b. Prefetch bookkeeping + predictor ranking (pure CPU, per decode
    // layer on the hot path when speculation is on).
    let mut queue = PrefetchQueue::new(1 << 20);
    common::time("prefetch budget spend+reset", 10_000, || {
        queue.begin_step();
        for _ in 0..8 {
            let _ = queue.try_spend(1024);
        }
    });
    let mut ewma = EwmaPopularity::new(dims.n_layers, dims.n_experts, 0.25);
    let active = vec![true; dims.b_max];
    common::time("ewma observe+predict", 10_000, || {
        ewma.observe(&LayerObservation {
            step: 0,
            layer: 0,
            n_experts: dims.n_experts,
            top_k: dims.top_k,
            probs: &probs[..dims.b_max * dims.n_experts],
            active: &active,
        });
        let _ = ewma.predict(&PredictCtx {
            step: 0,
            layer: 0,
            n_experts: dims.n_experts,
            top_k: dims.top_k,
            active: &active,
            lookahead_probs: None,
        });
    });

    // 5. Expert stage execution (PJRT, decode batch).
    let payload = model.payload_base(0, 0, Precision::Int(2), "hqq")?;
    let refs: Vec<&Tensor> = payload.iter().collect();
    let xn = model.make_x(dims.b_max, &vec![0.1f32; dims.b_max * dims.d_model])?;
    common::time("run_expert int2 decode (stage)", 50, || {
        let _ = model.run_expert(Precision::Int(2), false, &xn, &refs).unwrap();
    });
    let payload_c = model.payload_comp(0, 0, 2, "default")?;
    let refs_c: Vec<&Tensor> = payload.iter().chain(payload_c.iter()).collect();
    common::time("run_expert int2+comp decode (stage)", 50, || {
        let _ = model
            .run_expert(Precision::IntComp(2), false, &xn, &refs_c)
            .unwrap();
    });

    // 5b. Individual non-expert stages.
    {
        let (kc, vc) = model.empty_caches()?;
        let pos: Vec<i32> = vec![3; dims.b_max];
        let x = model.make_x(dims.b_max, &vec![0.1f32; dims.b_max * dims.d_model])?;
        common::time("attn_decode stage", 50, || {
            let _ = model.attn_decode(0, &x, &kc, &vc, &pos).unwrap();
        });
        common::time("router stage", 50, || {
            let _ = model.router(0, &x, false).unwrap();
        });
        common::time("embed stage", 50, || {
            let _ = model.embed(&vec![1i32; dims.b_max], false).unwrap();
        });
        common::time("head stage", 50, || {
            let _ = model.head(&x).unwrap();
        });
    }

    // 6. End-to-end decode steps (the serving inner loop).
    let sys = SystemConfig::scaled_for(&dims, false);
    let mut se = ServeEngine::new(
        StagedModel::load(Arc::clone(&backend), Manifest::load("artifacts/mixtral-tiny")?)?,
        PolicyConfig::new("beam", 2, dims.top_n),
        sys,
    )?;
    let eval = WeightStore::load(se.model().manifest.eval_path())?;
    let requests = WorkloadGen::generate(&WorkloadConfig::offline(4, 64, 4), &eval)?;
    serve(&mut se, requests)?; // warm: prefill + a few steps, caches hot
    let requests = WorkloadGen::generate(&WorkloadConfig::offline(4, 64, 24), &eval)?;
    let t0 = std::time::Instant::now();
    let r = serve(&mut se, requests)?;
    println!(
        "  decode loop: {} steps in {:.2}s wall => {:.1} ms/step ({} backend execs, {:.2} wall tok/s)",
        r.decode_steps,
        t0.elapsed().as_secs_f64(),
        1e3 * t0.elapsed().as_secs_f64() / r.decode_steps.max(1) as f64,
        r.backend_execs,
        r.wall_tokens_per_second(),
    );

    // 7. Same loop with gate-lookahead prefetching: the extra wall cost is
    // one router stage + queue bookkeeping per decode layer.
    let budget = dims.top_k
        * dims.n_layers
        * Manifest::load("artifacts/mixtral-tiny")?.q_expert_bytes(2);
    let mut se = ServeEngine::with_prefetch(
        StagedModel::load(Arc::clone(&backend), Manifest::load("artifacts/mixtral-tiny")?)?,
        PolicyConfig::new("beam", 2, dims.top_n),
        SystemConfig::scaled_for(&dims, false),
        PrefetchConfig::new("gate", 1, budget),
    )?;
    let requests = WorkloadGen::generate(&WorkloadConfig::offline(4, 64, 24), &eval)?;
    let t0 = std::time::Instant::now();
    let r = serve(&mut se, requests)?;
    println!(
        "  decode loop + gate prefetch: {} steps in {:.2}s wall => {:.1} ms/step (stall {:.4}s, cover {:.0}%)",
        r.decode_steps,
        t0.elapsed().as_secs_f64(),
        1e3 * t0.elapsed().as_secs_f64() / r.decode_steps.max(1) as f64,
        r.breakdown.transfer_stall_s,
        100.0 * r.prefetch.coverage(),
    );
    Ok(())
}
// (appended by perf pass) — per-stage timings live in stage_times bench below.

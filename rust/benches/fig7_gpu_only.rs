//! Bench: paper Fig. 7 (top) — GPU-only offloading throughput.
//!
//! Serves the paper's workload (in=256, out∈{128,256}) under
//! Mixtral-Offloading / HOBBIT / BEAM-3bit / BEAM-2bit on both models and
//! prints tokens/s (virtual) + speedups. `cargo bench --bench fig7_gpu_only`.

mod common;

use std::path::PathBuf;
use std::time::Instant;

use beam_moe::harness::figures::Harness;
use beam_moe::config::PolicyConfig;
use beam_moe::manifest::Manifest;

fn main() -> anyhow::Result<()> {
    common::header("fig7 (GPU-only): serving throughput");
    let h = Harness::new(PathBuf::from("artifacts"), Some(PathBuf::from("reports")), false)?;
    for model in ["mixtral-tiny", "deepseek-tiny"] {
        let top_n = Manifest::load(format!("artifacts/{model}"))?.model.top_n;
        println!("-- {model} --");
        let mut base = 0.0;
        for (name, policy) in [
            ("mixtral-offload", PolicyConfig::new("mixtral-offload", 16, 0)),
            ("hobbit", PolicyConfig::new("hobbit", 4, 0)),
            ("beam-3bit", PolicyConfig::new("beam", 3, top_n)),
            ("beam-2bit", PolicyConfig::new("beam", 2, top_n)),
        ] {
            for out_len in [128usize, 256] {
                let t0 = Instant::now();
                let r = h.serve_point(model, policy.clone(), false, out_len)?;
                let tps = r.tokens_per_second();
                if base == 0.0 {
                    base = tps;
                }
                println!(
                    "  {name:<18} out={out_len:<4} {tps:>9.2} tok/s ({:>5.2}x)  [wall {:.1}s]",
                    tps / base,
                    t0.elapsed().as_secs_f64()
                );
            }
        }
    }
    Ok(())
}

//! Predictive expert prefetching, live (DESIGN.md §8).
//!
//! Serves the same workload on the synthetic model (no artifacts needed)
//! under every predictor — demand-only, EWMA popularity, gate lookahead,
//! oracle replay — and prints what speculation buys: virtual throughput,
//! the decode weight-transfer stall it removes, coverage of demand
//! fetches, and the speculative/wasted byte bill.
//!
//! ```sh
//! cargo run --release --example prefetch_demo
//! ```

use std::sync::Arc;

use anyhow::Result;
use beam_moe::backend::{Backend, ReferenceBackend};
use beam_moe::config::{PolicyConfig, PrefetchConfig, SystemConfig};
use beam_moe::coordinator::Report;
use beam_moe::server::{Server, ServerBuilder};
use beam_moe::synth;
use beam_moe::workload::{Request, WorkloadConfig, WorkloadGen};

fn server(prefetch: PrefetchConfig) -> Result<Server> {
    let backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new());
    let model = synth::tiny_model(backend, "synthetic-tiny")?;
    let dims = model.manifest.model.clone();
    let mut sys = SystemConfig::scaled_for(&dims, false);
    // Offloading regime: the cache holds ~5 of the 8 quantized experts.
    sys.gpu_cache_bytes = 5 * model.manifest.q_expert_bytes(synth::SYNTH_BITS);
    ServerBuilder::new(model)
        .policy(PolicyConfig::new("beam", synth::SYNTH_BITS, 1))
        .system(sys)
        .prefetch(prefetch)
        .build()
}

fn requests() -> Result<Vec<Request>> {
    let dims = synth::tiny_dims("synthetic-tiny");
    let eval = synth::tiny_eval_store(&dims)?;
    WorkloadGen::generate(&WorkloadConfig::offline(3, 32, 12), &eval)
}

fn run(server: &mut Server) -> Result<Report> {
    for req in requests()? {
        server.submit(req)?;
    }
    server.run_to_completion()
}

fn row(name: &str, r: &Report) {
    println!(
        "{:<16} {:>9.2} tok/s | stall {:>8.5}s | cover {:>5.1}% | spec {:>7}B | wasted {:>7}B",
        name,
        r.tokens_per_second(),
        r.breakdown.transfer_stall_s,
        100.0 * r.prefetch.coverage(),
        r.prefetch.speculative_bytes,
        r.prefetch.wasted_bytes,
    );
}

fn main() -> Result<()> {
    let dims = synth::tiny_dims("synthetic-tiny");
    let budget = dims.top_k
        * dims.n_layers
        * synth::tiny_manifest("synthetic-tiny").q_expert_bytes(synth::SYNTH_BITS);
    println!("== speculative prefetching (synthetic, BEAM int2, budget {budget}B/step) ==");

    // Demand-only baseline (doubles as the oracle's recording pass).
    let mut base = server(PrefetchConfig::off())?;
    base.record_trace();
    let base_report = run(&mut base)?;
    row("demand-only", &base_report);
    let trace = base.take_trace()?;

    for name in ["ewma", "gate-lookahead", "oracle-replay"] {
        let mut s = server(PrefetchConfig::new(name, 1, budget))?;
        if s.needs_recorded_trace() {
            s.install_oracle_trace(&trace);
        }
        let r = run(&mut s)?;
        row(name, &r);
    }

    println!("\ntails (demand-only): {}", base_report.tail_line());
    println!("(stall = decode critical-path wait on weight transfers; prefetching shrinks it)");
    Ok(())
}

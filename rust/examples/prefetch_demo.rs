//! Predictive expert prefetching, live (DESIGN.md §8).
//!
//! Serves the same workload on the synthetic model (no artifacts needed)
//! under every predictor — demand-only, EWMA popularity, gate lookahead,
//! oracle replay — and prints what speculation buys: virtual throughput,
//! the decode weight-transfer stall it removes, coverage of demand
//! fetches, and the speculative/wasted byte bill.
//!
//! ```sh
//! cargo run --release --example prefetch_demo
//! ```

use std::sync::Arc;

use anyhow::Result;
use beam_moe::backend::{Backend, ReferenceBackend};
use beam_moe::config::{
    PolicyConfig, PolicyKind, PredictorKind, PrefetchConfig, SystemConfig,
};
use beam_moe::coordinator::scheduler::serve;
use beam_moe::coordinator::{Report, ServeEngine};
use beam_moe::synth;
use beam_moe::workload::{DecodeTrace, Request, WorkloadConfig, WorkloadGen};

fn engine(prefetch: PrefetchConfig) -> Result<ServeEngine> {
    let backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new());
    let model = synth::tiny_model(backend, "synthetic-tiny")?;
    let dims = model.manifest.model.clone();
    let mut sys = SystemConfig::scaled_for(&dims, false);
    // Offloading regime: the cache holds ~5 of the 8 quantized experts.
    sys.gpu_cache_bytes = 5 * model.manifest.q_expert_bytes(synth::SYNTH_BITS);
    let policy = PolicyConfig::new(PolicyKind::Beam, synth::SYNTH_BITS, 1);
    ServeEngine::with_prefetch(model, policy, sys, prefetch)
}

fn requests() -> Result<Vec<Request>> {
    let dims = synth::tiny_dims("synthetic-tiny");
    let eval = synth::tiny_eval_store(&dims)?;
    WorkloadGen::generate(&WorkloadConfig::offline(3, 32, 12), &eval)
}

fn row(name: &str, r: &Report) {
    println!(
        "{:<16} {:>9.2} tok/s | stall {:>8.5}s | cover {:>5.1}% | spec {:>7}B | wasted {:>7}B",
        name,
        r.tokens_per_second(),
        r.breakdown.transfer_stall_s,
        100.0 * r.prefetch.coverage(),
        r.prefetch.speculative_bytes,
        r.prefetch.wasted_bytes,
    );
}

fn main() -> Result<()> {
    let dims = synth::tiny_dims("synthetic-tiny");
    let budget = dims.top_k
        * dims.n_layers
        * synth::tiny_manifest("synthetic-tiny").q_expert_bytes(synth::SYNTH_BITS);
    println!("== speculative expert prefetching (synthetic model, BEAM int2, budget {budget}B/step) ==");

    // Demand-only baseline (doubles as the oracle's recording pass).
    let mut base = engine(PrefetchConfig::off())?;
    base.trace = Some(DecodeTrace::default());
    let base_report = serve(&mut base, requests()?)?;
    row("demand-only", &base_report);
    let trace = base.trace.take().unwrap();

    for (name, kind) in [
        ("ewma", PredictorKind::Ewma),
        ("gate-lookahead", PredictorKind::GateLookahead),
        ("oracle-replay", PredictorKind::OracleReplay),
    ] {
        let mut e = engine(PrefetchConfig::new(kind, 1, budget))?;
        if kind == PredictorKind::OracleReplay {
            e.set_oracle_trace(&trace);
        }
        let r = serve(&mut e, requests()?)?;
        row(name, &r);
    }

    println!("\ntails (demand-only): {}", base_report.tail_line());
    println!("(stall = decode critical-path wait on weight transfers; prefetching exists to shrink it)");
    Ok(())
}

//! GPU-NDP deployment scenario (paper §4.3 case study 2).
//!
//! Serves the same workload on the GPU-NDP testbed under MoNDE (fp16
//! near-data experts) and BEAM (low-bit near-data + router-guided top-n
//! compensation on the GPU), then prints where the bytes and the time went
//! on each device — making the paper's "hybrid execution with lower
//! bandwidth demand" claim inspectable.
//!
//! ```sh
//! cargo run --release --example ndp_offload [model]
//! ```

use anyhow::Result;
use beam_moe::backend::default_backend;
use beam_moe::config::{PolicyConfig, SystemConfig};
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::runtime::StagedModel;
use beam_moe::server::ServerBuilder;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};
use std::sync::Arc;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model_name = args.get(1).map(|s| s.as_str()).unwrap_or("mixtral-tiny");
    let backend = default_backend()?;
    let manifest = Manifest::load(format!("artifacts/{model_name}"))?;
    let top_n = manifest.model.top_n;

    println!("== GPU-NDP offloading: {model_name} (NDP 512 GB/s, scaled) ==\n");
    let policies: Vec<(&str, PolicyConfig)> = vec![
        ("monde(fp16-ndp)", PolicyConfig::new("monde", 16, 0)),
        ("beam(int3)", PolicyConfig::new("beam", 3, top_n)),
        ("beam(int2)", PolicyConfig::new("beam", 2, top_n)),
    ];

    for (name, policy) in policies {
        let model = StagedModel::load(
            Arc::clone(&backend),
            Manifest::load(format!("artifacts/{model_name}"))?,
        )?;
        let sys = SystemConfig::scaled_for(&model.manifest.model, true);
        let mut server = ServerBuilder::new(model).policy(policy).system(sys).build()?;
        let eval = WeightStore::load(server.model().manifest.eval_path())?;
        for req in WorkloadGen::generate(&WorkloadConfig::offline(4, 256, 64), &eval)? {
            server.submit(req)?;
        }
        let r = server.run_to_completion()?;
        println!("{name}");
        println!("  {:.2} tok/s (virtual)", r.tokens_per_second());
        let b = &r.breakdown;
        println!(
            "  time: gpu-experts {:.4}s | ndp-experts {:.4}s | weight-xfer {:.4}s | comp-xfer {:.4}s | act-xfer {:.4}s",
            b.expert_compute_s,
            b.ndp_compute_s,
            b.transfer_weights_s,
            b.transfer_comp_s,
            b.transfer_act_s,
        );
        println!(
            "  bytes: weights {} | compensators {} | activations {}\n",
            r.bytes.get("expert_weights").unwrap_or(&0),
            r.bytes.get("compensator").unwrap_or(&0),
            r.bytes.get("activations").unwrap_or(&0),
        );
    }
    println!("(paper: BEAM gains 4.75-6.69x over MoNDE via low-bit near-data experts)");
    Ok(())
}

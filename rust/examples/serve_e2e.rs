//! End-to-end serving driver (the DESIGN.md-required E2E validation).
//!
//! Loads the trained tiny MoE LM, serves a batched workload (offline
//! arrival, 256-token prompts) through *every* policy on the GPU-only
//! testbed, and reports per-policy latency/throughput — the live version
//! of the paper's Fig. 7 (GPU case) plus request-latency percentiles the
//! paper does not show.
//!
//! ```sh
//! cargo run --release --example serve_e2e [model] [requests] [output_len]
//! ```

use anyhow::Result;
use beam_moe::backend::default_backend;
use beam_moe::config::{PolicyConfig, SystemConfig};
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::runtime::StagedModel;
use beam_moe::server::ServerBuilder;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};
use std::sync::Arc;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model_name = args.get(1).map(|s| s.as_str()).unwrap_or("mixtral-tiny");
    let n_requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);
    let output_len: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(64);

    let backend = default_backend()?;
    let manifest = Manifest::load(format!("artifacts/{model_name}"))?;
    let top_n = manifest.model.top_n;
    println!(
        "== end-to-end serving: {model_name}, {n_requests} requests, in=256 out={output_len} =="
    );

    let policies: Vec<(&str, PolicyConfig)> = vec![
        ("mixtral-offload(fp16)", PolicyConfig::new("mixtral-offload", 16, 0)),
        ("hobbit(mixed)", PolicyConfig::new("hobbit", 4, 0)),
        ("static-quant(int2)", PolicyConfig::new("static-quant", 2, 0)),
        ("beam(int3+top-n)", PolicyConfig::new("beam", 3, top_n)),
        ("beam(int2+top-n)", PolicyConfig::new("beam", 2, top_n)),
    ];

    println!(
        "{:<22} {:>10} {:>9} {:>9} {:>9} {:>8} {:>8}",
        "policy", "tok/s(sim)", "ttft(s)", "lat(s)", "xfer%", "hit%", "wall(s)"
    );
    let mut baseline = 0.0;
    for (name, policy) in policies {
        let model = StagedModel::load(
            Arc::clone(&backend),
            Manifest::load(format!("artifacts/{model_name}"))?,
        )?;
        let sys = SystemConfig::scaled_for(&model.manifest.model, false);
        let mut server = ServerBuilder::new(model).policy(policy).system(sys).build()?;
        let eval = WeightStore::load(server.model().manifest.eval_path())?;
        let wl = WorkloadConfig::offline(n_requests, 256, output_len);
        for req in WorkloadGen::generate(&wl, &eval)? {
            server.submit(req)?;
        }
        let r = server.run_to_completion()?;
        let tps = r.tokens_per_second();
        if baseline == 0.0 {
            baseline = tps;
        }
        let xfer = 100.0 * r.breakdown.total_transfer()
            / (r.breakdown.total_transfer() + r.breakdown.total_compute());
        println!(
            "{:<22} {:>10.2} {:>9.4} {:>9.4} {:>8.1}% {:>7.1}% {:>8.1}  ({:.2}x)",
            name,
            tps,
            r.mean_ttft(),
            r.mean_request_latency(),
            xfer,
            100.0 * r.cache_hit_rate,
            r.wall_seconds,
            tps / baseline,
        );
        println!("{:<22} {}", "", r.tail_line());
    }
    println!("\n(speedups vs fp16 offloading; paper Fig. 7 reports 5.2-7.6x for BEAM)");
    Ok(())
}

//! Accuracy evaluation through the serving numerics (paper Fig. 6 live).
//!
//! Teacher-forced scoring of held-out synthetic-corpus sequences through
//! the *staged PJRT path* — the same kernels, payloads and per-token
//! compensation decisions the server makes — under fp16 / HQQ / GPTQ /
//! BEAM at 2- and 3-bit.
//!
//! ```sh
//! cargo run --release --example accuracy_eval [model] [n_seqs]
//! ```

use anyhow::Result;
use beam_moe::config::PolicyConfig;
use beam_moe::harness::figures::Harness;
use beam_moe::manifest::Manifest;
use std::path::PathBuf;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).map(|s| s.as_str()).unwrap_or("mixtral-tiny");
    let n: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(16);

    let h = Harness::new(PathBuf::from("artifacts"), None, false)?;
    let manifest = Manifest::load(format!("artifacts/{model}"))?;
    let top_n = manifest.model.top_n;
    let has_gptq = manifest.quant.methods.iter().any(|m| m == "gptq");

    println!("== accuracy eval: {model}, {n} held-out sequences ==");
    println!("{:<10} {:>10} {:>10}", "variant", "ppl", "cloze%");

    let mut variants: Vec<(String, PolicyConfig)> =
        vec![("fp16".into(), PolicyConfig::new("mixtral-offload", 16, 0))];
    for bits in [3u8, 2] {
        if has_gptq {
            let mut p = PolicyConfig::new("static-quant", bits, 0);
            p.method = "gptq".into();
            variants.push((format!("gptq{bits}"), p));
        }
        variants.push((format!("hqq{bits}"), PolicyConfig::new("static-quant", bits, 0)));
        variants.push((format!("beam{bits}"), PolicyConfig::new("beam", bits, top_n)));
    }
    for (name, policy) in variants {
        let (ppl, acc) = h.score_variant(model, policy, n)?;
        println!("{name:<10} {ppl:>10.3} {:>9.1}%", acc * 100.0);
    }
    println!("\n(expected: beam recovers most of the hqq→fp16 gap; gptq collapses at 2-bit)");
    Ok(())
}

//! Heterogeneity-aware precision allocation, live (DESIGN.md §10).
//!
//! Serves the same workload on the synthetic model (no artifacts needed)
//! under uniform `static-quant` and the `adaptive` policy at a ladder of
//! equal byte budgets, printing what spending the *same* bytes
//! non-uniformly buys: the allocator's plan census, throughput, decode
//! weight-transfer stall, and the demand-weighted FFN-vs-fp16 weight
//! error the compensated hot experts claw back.
//!
//! ```sh
//! cargo run --release --example adaptive_demo
//! ```

use std::sync::Arc;

use anyhow::{Context, Result};
use beam_moe::backend::{Backend, ReferenceBackend};
use beam_moe::config::{PolicyConfig, Precision, SystemConfig};
use beam_moe::coordinator::Report;
use beam_moe::harness::figures::demand_weighted_error;
use beam_moe::synth;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};

fn serve(policy: PolicyConfig) -> Result<Report> {
    let backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new());
    let model = synth::tiny_model(backend, "synthetic-tiny")?;
    let dims = model.manifest.model.clone();
    let mut sys = SystemConfig::scaled_for(&dims, false);
    // Offloading regime: the cache holds ~5 of the 8 quantized experts.
    sys.gpu_cache_bytes = 5 * model.manifest.q_expert_bytes(synth::SYNTH_BITS);
    let mut server = beam_moe::server::ServerBuilder::new(model).policy(policy).system(sys).build()?;
    let eval = synth::tiny_eval_store(&dims)?;
    for req in WorkloadGen::generate(&WorkloadConfig::offline(3, 32, 12), &eval)? {
        server.submit(req)?;
    }
    server.run_to_completion()
}

fn main() -> Result<()> {
    let manifest = synth::tiny_manifest("synthetic-tiny");
    let dims = manifest.model.clone();
    let floor = dims.n_layers * dims.n_experts * manifest.q_expert_bytes(synth::SYNTH_BITS);
    let comp_total = manifest.comp_bytes_total("default", synth::SYNTH_BITS);
    println!(
        "== adaptive per-expert precision (synthetic, floor int{}, floor plan {floor}B) ==",
        synth::SYNTH_BITS
    );

    let uni = serve(PolicyConfig::new("static-quant", synth::SYNTH_BITS, 0))?;
    println!(
        "{:<22} {:>8.2} tok/s | stall {:>8.5}s | comp bytes {:>6}",
        "static-quant (uniform)",
        uni.tokens_per_second(),
        uni.breakdown.transfer_stall_s,
        uni.bytes.get("compensator").copied().unwrap_or(0),
    );

    let probe_backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new());
    let probe = synth::tiny_model(probe_backend, "synthetic-tiny")?;
    let uniform_assignment =
        vec![vec![Precision::Int(synth::SYNTH_BITS); dims.n_experts]; dims.n_layers];

    for (label, budget) in [
        ("budget = floor", floor),
        ("floor + comp/2", floor + comp_total / 2),
        ("floor + comp", floor + comp_total),
    ] {
        let mut cfg = PolicyConfig::new("adaptive", synth::SYNTH_BITS, 0);
        cfg.alloc_budget_bytes = Some(budget);
        let r = serve(cfg)?;
        let alloc = r.alloc.as_ref().context("adaptive reports its allocator state")?;
        let e_uni = demand_weighted_error(&probe, &uniform_assignment, &alloc.scores, "default")?;
        let e_ada = demand_weighted_error(&probe, &alloc.assignment, &alloc.scores, "default")?;
        println!(
            "{label:<22} {:>8.2} tok/s | stall {:>8.5}s | comp bytes {:>6} | werr {e_ada:.4} (uniform {e_uni:.4})",
            r.tokens_per_second(),
            r.breakdown.transfer_stall_s,
            r.bytes.get("compensator").copied().unwrap_or(0),
        );
        println!("{:<22} {}", "", alloc.summary());
    }
    println!("(equal bytes, spent by routing demand: hot experts earn compensation first)");
    Ok(())
}

//! Router dynamics inspection (paper Fig. 2 + Fig. 3 live).
//!
//! Decodes one sequence with routing tracing enabled and prints the
//! per-step expert activation heat-map, the expert-set switch rate per
//! layer (Fig. 2's "irregular activation"), and the calibration-set router
//! score distribution (Fig. 3) from the artifacts.
//!
//! ```sh
//! cargo run --release --example router_stats [model]
//! ```

use anyhow::Result;
use beam_moe::backend::default_backend;
use beam_moe::config::{PolicyConfig, SystemConfig};
use beam_moe::jsonx::Value;
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::runtime::StagedModel;
use beam_moe::server::ServerBuilder;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model_name = args.get(1).map(|s| s.as_str()).unwrap_or("mixtral-tiny");

    let backend = default_backend()?;
    let model = StagedModel::load(backend, Manifest::load(format!("artifacts/{model_name}"))?)?;
    let dims = model.manifest.model.clone();
    let sys = SystemConfig::scaled_for(&dims, false);
    let mut server = ServerBuilder::new(model)
        .policy(PolicyConfig::new("beam", 2, dims.top_n))
        .system(sys)
        .build()?;
    server.record_trace();

    let eval = WeightStore::load(server.model().manifest.eval_path())?;
    for req in WorkloadGen::generate(&WorkloadConfig::offline(1, 64, 40), &eval)? {
        server.submit(req)?;
    }
    server.run_to_completion()?;
    let trace = server.take_trace()?;

    println!("== expert activation over decode steps (layer 0, '#'=dominant '+'=secondary) ==");
    for (step, row) in trace.activation_matrix(0, dims.n_experts).iter().enumerate().take(24) {
        let cells: String = row
            .iter()
            .map(|&w| match w {
                w if w > 0.5 => '#',
                w if w > 0.25 => '+',
                w if w > 0.0 => '.',
                _ => ' ',
            })
            .collect();
        println!("  step {step:>3} |{cells}|");
    }
    for l in 0..dims.n_layers {
        println!("  layer {l}: switch rate {:.2}", trace.switch_rate(l));
    }

    println!("\n== router score distribution (Fig. 3, from calibration) ==");
    let raw = std::fs::read_to_string(format!("artifacts/{model_name}/router_stats.json"))?;
    let stats = Value::parse(&raw)?;
    let mean = stats.get("mean_over_layers")?.f64_vec()?;
    for (rank, m) in mean.iter().enumerate().take(dims.top_k.max(4)) {
        println!("  rank-{rank} mean score: {m:.3}");
    }
    let t1 = stats.get("top1_range")?.f64_vec()?;
    println!("  top-1 share across layers: {:.2}..{:.2}", t1[0], t1[1]);
    Ok(())
}

//! Quickstart: load a BEAM model and serve two short requests.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface in ~40 lines: manifest → backend →
//! staged model → serve engine with the paper's policy → report.  With no
//! `artifacts/` directory (no python run), it falls back to the built-in
//! synthetic tiny model, so the command above works from a clean checkout
//! on the pure-Rust reference backend.  After `make artifacts`, the same
//! binary serves the trained mixtral-tiny instead.

use std::path::Path;

use std::sync::Arc;

use anyhow::Result;
use beam_moe::backend::{default_backend, Backend, ReferenceBackend};
use beam_moe::config::{PolicyConfig, PolicyKind, SystemConfig};
use beam_moe::coordinator::scheduler::serve;
use beam_moe::coordinator::ServeEngine;
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::runtime::StagedModel;
use beam_moe::synth;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};

fn main() -> Result<()> {
    // Model + backend: trained artifacts on the build's default backend
    // when present; otherwise the synthetic tiny model (zero-artifact path
    // — see rust/src/synth.rs), which has no HLO files and therefore
    // always runs on the reference backend, even in a `pjrt` build.
    let art = Path::new("artifacts/mixtral-tiny");
    let (model, eval, bits) = if art.join("manifest.json").exists() {
        let backend = default_backend()?;
        println!("backend: {}", backend.platform());
        let model = StagedModel::load(backend, Manifest::load(art)?)?;
        let eval = WeightStore::load(model.manifest.eval_path())?;
        (model, eval, 2u8)
    } else {
        let backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new());
        println!("artifacts/ not found — synthetic model on the {} backend", backend.platform());
        let model = synth::tiny_model(backend, "synthetic-tiny")?;
        let eval = synth::tiny_eval_store(&model.manifest.model)?;
        (model, eval, synth::SYNTH_BITS)
    };
    println!(
        "model {}: {} layers × {} experts (top-{}), d={}",
        model.manifest.model.name,
        model.manifest.model.n_layers,
        model.manifest.model.n_experts,
        model.manifest.model.top_k,
        model.manifest.model.d_model
    );

    // Policy: the paper's router-guided compensation at low-bit, top-1.
    let policy = PolicyConfig::new(PolicyKind::Beam, bits, 1);
    let sys = SystemConfig::scaled_for(&model.manifest.model, false);
    let mut serve_engine = ServeEngine::new(model, policy, sys)?;

    // Two requests from the corpus token dump, 24 output tokens each.
    let wl = WorkloadConfig::offline(2, 48, 24);
    let requests = WorkloadGen::generate(&wl, &eval)?;

    // Serve and report.
    let report = serve(&mut serve_engine, requests)?;
    println!("{}", report.summary_line());
    println!(
        "generated {} tokens in {:.4} virtual s  ({:.1} tok/s on the simulated H100 testbed)",
        report.total_generated,
        report.virtual_seconds,
        report.tokens_per_second()
    );
    println!(
        "bytes moved: weights {} | compensators {} (the paper's extra traffic)",
        report.bytes.get("expert_weights").unwrap_or(&0),
        report.bytes.get("compensator").unwrap_or(&0),
    );
    Ok(())
}

//! Quickstart: build a `Server` and stream tokens from two sessions.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface in ~50 lines: manifest → backend →
//! staged model → `ServerBuilder` → per-request `Session` token-event
//! streams → report.  With no `artifacts/` directory (no python run), it
//! falls back to the built-in synthetic tiny model, so the command above
//! works from a clean checkout on the pure-Rust reference backend.  After
//! `make artifacts`, the same binary serves the trained mixtral-tiny.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;
use beam_moe::backend::{default_backend, Backend, ReferenceBackend};
use beam_moe::config::{PolicyConfig, SystemConfig};
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::runtime::StagedModel;
use beam_moe::server::{ServerBuilder, ServerTick, TokenEvent};
use beam_moe::synth;
use beam_moe::workload::{WorkloadConfig, WorkloadGen};

fn main() -> Result<()> {
    // Model + backend: trained artifacts on the build's default backend
    // when present; otherwise the synthetic tiny model (zero-artifact path
    // — see rust/src/synth.rs), which has no HLO files and therefore
    // always runs on the reference backend, even in a `pjrt` build.
    let art = Path::new("artifacts/mixtral-tiny");
    let (model, eval, bits) = if art.join("manifest.json").exists() {
        let backend = default_backend()?;
        println!("backend: {}", backend.platform());
        let model = StagedModel::load(backend, Manifest::load(art)?)?;
        let eval = WeightStore::load(model.manifest.eval_path())?;
        (model, eval, 2u8)
    } else {
        let backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new());
        println!("artifacts/ not found — synthetic model on the {} backend", backend.platform());
        let model = synth::tiny_model(backend, "synthetic-tiny")?;
        let eval = synth::tiny_eval_store(&model.manifest.model)?;
        (model, eval, synth::SYNTH_BITS)
    };
    println!(
        "model {}: {} layers × {} experts (top-{}), d={}",
        model.manifest.model.name,
        model.manifest.model.n_layers,
        model.manifest.model.n_experts,
        model.manifest.model.top_k,
        model.manifest.model.d_model
    );

    // Server: the paper's router-guided compensation policy at low-bit,
    // top-1, on the simulated H100 testbed scaled for this model.
    let sys = SystemConfig::scaled_for(&model.manifest.model, false);
    let mut server = ServerBuilder::new(model)
        .policy(PolicyConfig::new("beam", bits, 1))
        .system(sys)
        .build()?;

    // Two requests from the corpus token dump, 24 output tokens each,
    // submitted one at a time (admission-controlled — no up-front Vec).
    let wl = WorkloadConfig::offline(2, 48, 24);
    let mut ids = Vec::new();
    for req in WorkloadGen::generate(&wl, &eval)? {
        ids.push(server.submit(req)?);
    }

    // Drive the deterministic event loop, streaming session 0's first
    // tokens with their virtual timestamps as they are generated.
    let report = loop {
        let tick = server.tick()?;
        for ev in server.poll_events(ids[0]) {
            match ev {
                TokenEvent::Admitted { at } => println!("  [{}] admitted at {at:.4}s", ids[0]),
                TokenEvent::Token { token, index, at } if index < 4 => {
                    println!("  [{}] token[{index}] = {token} at {at:.4}s", ids[0]);
                }
                TokenEvent::Finished { at } => println!("  [{}] finished at {at:.4}s", ids[0]),
                _ => {}
            }
        }
        if tick == ServerTick::Done {
            break server.report();
        }
    };

    println!("{}", report.summary_line());
    println!(
        "generated {} tokens in {:.4} virtual s  ({:.1} tok/s on the simulated H100 testbed)",
        report.total_generated,
        report.virtual_seconds,
        report.tokens_per_second()
    );
    println!(
        "bytes moved: weights {} | compensators {} (the paper's extra traffic)",
        report.bytes.get("expert_weights").unwrap_or(&0),
        report.bytes.get("compensator").unwrap_or(&0),
    );
    Ok(())
}

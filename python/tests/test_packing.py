"""Bit-packing codec tests: exact round-trips and byte accounting."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quant.packing import (
    container_bits,
    pack_codes,
    packed_nbytes,
    to_container,
    unpack_codes,
)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_roundtrip_exact(bits):
    rng = np.random.default_rng(bits)
    codes = rng.integers(0, 2**bits, size=(16, 64), dtype=np.uint8)
    packed = pack_codes(codes, bits)
    assert packed.shape[0] == 16
    out = unpack_codes(packed, bits, 64)
    np.testing.assert_array_equal(out, codes)


@settings(max_examples=60, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    rows=st.integers(1, 8),
    chunks=st.integers(1, 16),
    seed=st.integers(0, 2**31),
)
def test_roundtrip_hypothesis(bits, rows, chunks, seed):
    cpc = {2: 4, 3: 8, 4: 2, 8: 1}[bits]
    n = chunks * cpc
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 2**bits, size=(rows, n), dtype=np.uint8)
    out = unpack_codes(pack_codes(codes, bits), bits, n)
    np.testing.assert_array_equal(out, codes)


def test_packed_nbytes_ratios():
    assert packed_nbytes(1024, 2) == 256
    assert packed_nbytes(1024, 3) == 384
    assert packed_nbytes(1024, 4) == 512
    assert packed_nbytes(1024, 8) == 1024


def test_packed_nbytes_rejects_partial_chunks():
    with pytest.raises(ValueError):
        packed_nbytes(7, 3)


def test_pack_rejects_out_of_range_codes():
    with pytest.raises(ValueError):
        pack_codes(np.array([[4]], dtype=np.uint8), 2)


def test_3bit_pack_is_true_3_bits():
    codes = np.zeros((1, 64), dtype=np.uint8)
    assert pack_codes(codes, 3).shape[-1] == 24  # 64 * 3/8


def test_container_widens_only_3bit():
    assert container_bits(3) == 4
    assert container_bits(2) == 2
    assert container_bits(4) == 4
    assert container_bits(8) == 8


def test_to_container_3bit_is_4bit_packed():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 8, size=(4, 16), dtype=np.uint8)
    cont = to_container(codes, 3)
    assert cont.shape[-1] == 8  # 16 codes at 4 bits
    np.testing.assert_array_equal(unpack_codes(cont, 4, 16), codes)


def test_3bit_known_pattern():
    # 8 codes [0..7] -> word 0b111_110_101_100_011_010_001_000 = 0xFAC688
    codes = np.arange(8, dtype=np.uint8)[None, :]
    packed = pack_codes(codes, 3)
    word = int(packed[0, 0]) | (int(packed[0, 1]) << 8) | (int(packed[0, 2]) << 16)
    for j in range(8):
        assert (word >> (3 * j)) & 7 == j

"""Synthetic corpus: determinism, structure, split hygiene."""

import numpy as np

from compile.corpus import (
    CALIB_SEQS,
    CALIB_START,
    CNT,
    REP,
    SEP,
    SyntheticCorpus,
    TRAIN_SEQS,
    TRAIN_START,
    VAL_SEQS,
    VAL_START,
)


def test_determinism():
    a, da = SyntheticCorpus().batch(17, 8)
    b, db = SyntheticCorpus().batch(17, 8)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(da, db)


def test_tokens_in_vocab():
    toks, _ = SyntheticCorpus().batch(0, 64)
    assert toks.min() >= 0
    assert toks.max() < 512


def test_shapes():
    toks, det = SyntheticCorpus().batch(0, 5)
    assert toks.shape == (5, 64)
    assert det.shape == (5, 64)


def test_det_positions_exist_but_minority():
    _, det = SyntheticCorpus().batch(0, 64)
    frac = det.mean()
    assert 0.1 < frac < 0.6


def test_rep_motif_is_truly_determined():
    """Wherever a REP motif appears, marked positions repeat the a/b pair."""
    c = SyntheticCorpus()
    checked = 0
    for i in range(200):
        toks, det = c.sequence(i)
        for j in range(len(toks) - 6):
            if toks[j] == REP and det[j + 3] and j + 5 < len(toks):
                a, b = toks[j + 1], toks[j + 2]
                assert toks[j + 3] == a and toks[j + 4] == b and toks[j + 5] == a
                checked += 1
    assert checked > 10


def test_cnt_motif_is_consecutive():
    c = SyntheticCorpus()
    checked = 0
    for i in range(200):
        toks, det = c.sequence(i)
        for j in range(len(toks) - 5):
            if toks[j] == CNT and det[j + 2] and toks[j + 5] == SEP:
                assert toks[j + 2] == toks[j + 1] + 1
                assert toks[j + 3] == toks[j + 2] + 1
                checked += 1
    assert checked > 10


def test_splits_disjoint():
    assert TRAIN_START + TRAIN_SEQS <= VAL_START
    assert VAL_START + VAL_SEQS <= CALIB_START
    assert CALIB_SEQS > 0


def test_different_sequences_differ():
    c = SyntheticCorpus()
    a, _ = c.sequence(0)
    b, _ = c.sequence(1)
    assert (a != b).any()

"""L1 pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes/bit-widths; every kernel must match its `ref.py`
oracle to float32 tolerance (the interpret-mode kernel and the oracle share
no tiling/unpacking code).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.compensate import build_compensator
from compile.kernels import (
    decode_attention,
    expert_fp16,
    expert_quant,
    expert_quant_comp,
    lowrank_delta,
    quant_matmul,
)
from compile.kernels.ref import (
    ref_decode_attention,
    ref_expert_fp16,
    ref_expert_quant,
    ref_expert_quant_comp,
    ref_lowrank_delta,
    ref_quant_matmul,
)
from compile.quant import quantize_hqq, quantize_uniform
from compile.quant.packing import container_bits, to_container


def quant_args(W, bits, group=64):
    q = quantize_uniform(W, bits, group)
    cb = container_bits(bits)
    return (
        jnp.asarray(to_container(q.codes, bits)),
        jnp.asarray(q.scale),
        jnp.asarray(q.zero),
    ), cb, q


@settings(max_examples=20, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    b=st.integers(1, 8),
    din_g=st.integers(1, 3),
    dout=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31),
)
def test_quant_matmul_matches_ref(bits, b, din_g, dout, seed):
    rng = np.random.default_rng(seed)
    d_in = 64 * din_g
    W = rng.normal(size=(d_in, dout)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32))
    (pk, sc, zp), cb, _ = quant_args(W, bits)
    y = quant_matmul(x, pk, sc, zp, cbits=cb, group_size=64, d_out=dout)
    y_ref = ref_quant_matmul(x, pk, sc, zp, cbits=cb, group_size=64, d_out=dout)
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-4)


def test_quant_matmul_equals_dense_on_dequant():
    rng = np.random.default_rng(0)
    W = rng.normal(size=(128, 128)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    (pk, sc, zp), cb, q = quant_args(W, 4)
    y = quant_matmul(x, pk, sc, zp, cbits=cb, group_size=64, d_out=128)
    np.testing.assert_allclose(y, np.asarray(x) @ q.dequantize(), atol=1e-3)


def test_quant_matmul_tile_invariance():
    rng = np.random.default_rng(1)
    W = rng.normal(size=(128, 256)).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))
    (pk, sc, zp), cb, _ = quant_args(W, 2)
    full = quant_matmul(x, pk, sc, zp, cbits=cb, group_size=64, d_out=256, tile=256)
    tiled = quant_matmul(x, pk, sc, zp, cbits=cb, group_size=64, d_out=256, tile=64)
    np.testing.assert_allclose(full, tiled, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    rank=st.sampled_from([4, 8, 16, 64]),
    b=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_lowrank_delta_matches_ref(rank, b, seed):
    rng = np.random.default_rng(seed)
    d_in, d_out = 128, 256
    U = rng.normal(size=(d_in, rank)).astype(np.float32) * 0.05
    V = rng.normal(size=(rank, d_out)).astype(np.float32) * 0.05
    uq = quantize_uniform(U, 3, min(64, d_in))
    vq = quantize_uniform(V, 3, min(4, rank))
    x = jnp.asarray(rng.normal(size=(b, d_in)).astype(np.float32))
    args = (
        jnp.asarray(to_container(uq.codes, 3)), jnp.asarray(uq.scale), jnp.asarray(uq.zero),
        jnp.asarray(to_container(vq.codes, 3)), jnp.asarray(vq.scale), jnp.asarray(vq.zero),
    )
    y = lowrank_delta(x, *args, rank=rank, d_out=d_out)
    y_ref = ref_lowrank_delta(x, *args, rank=rank, d_out=d_out)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)


def test_expert_fp16_matches_ref():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32) * 0.1)
    w3 = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32) * 0.1)
    np.testing.assert_allclose(
        expert_fp16(x, w1, w2, w3), ref_expert_fp16(x, w1, w2, w3), atol=1e-4
    )


@settings(max_examples=8, deadline=None)
@given(bits=st.sampled_from([2, 3, 4]), seed=st.integers(0, 2**31))
def test_expert_quant_matches_ref(bits, seed):
    rng = np.random.default_rng(seed)
    d, f = 128, 256
    x = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    args = []
    for shape in [(d, f), (f, d), (d, f)]:
        W = rng.normal(size=shape).astype(np.float32) * 0.1
        (pk, sc, zp), cb, _ = quant_args(W, bits)
        args += [pk, sc, zp]
    y = expert_quant(x, *args, cbits=container_bits(bits), group_size=64, d_ff=f, d_out=d)
    y_ref = ref_expert_quant(
        x, *args, cbits=container_bits(bits), group_size=64, d_ff=f, d_out=d
    )
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-4)


def _comp_args(rng, shape, bits, rank_pad):
    W = rng.normal(size=shape).astype(np.float32) * 0.1
    q = quantize_hqq(W, bits, 64)
    c = build_compensator(W, q, 8, pad_to=rank_pad)
    w = (jnp.asarray(to_container(q.codes, bits)), jnp.asarray(q.scale), jnp.asarray(q.zero))
    comp = (
        jnp.asarray(to_container(c.u_q.codes, 3)), jnp.asarray(c.u_q.scale), jnp.asarray(c.u_q.zero),
        jnp.asarray(to_container(c.v_q.codes, 3)), jnp.asarray(c.v_q.scale), jnp.asarray(c.v_q.zero),
    )
    return w, comp


@pytest.mark.parametrize("bits", [2, 3])
def test_expert_quant_comp_matches_ref(bits):
    rng = np.random.default_rng(11)
    d, f, r = 128, 256, 64
    x = jnp.asarray(rng.normal(size=(4, d)).astype(np.float32))
    w1, c1 = _comp_args(rng, (d, f), bits, r)
    w2, c2 = _comp_args(rng, (f, d), bits, r)
    w3, c3 = _comp_args(rng, (d, f), bits, r)
    cb = container_bits(bits)
    y = expert_quant_comp(
        x, w1, w2, w3, c1, c2, c3,
        cbits=cb, group_size=64, d_ff=f, d_out=d, rank=r,
    )
    y_ref = ref_expert_quant_comp(
        x, w1, w2, w3, c1, c2, c3,
        cbits=cb, group_size=64, d_ff=f, d_out=d, rank=r,
    )
    np.testing.assert_allclose(y, y_ref, atol=1e-3, rtol=1e-4)


def test_compensated_expert_beats_plain_quant():
    """End-to-end: compensation must reduce output error vs the fp16 expert."""
    rng = np.random.default_rng(12)
    d, f, r = 128, 256, 64
    # Column-scaled weights -> spiked residual (the regime BEAM targets).
    def spiked(shape):
        W = rng.normal(size=shape).astype(np.float32) * 0.1
        return W * np.exp(rng.normal(size=(1, shape[1])) * 0.8).astype(np.float32)

    Ws = [spiked((d, f)), spiked((f, d)), spiked((d, f))]
    x = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))
    y_true = ref_expert_fp16(x, *(jnp.asarray(w) for w in Ws))

    args_q, args_w, args_c = [], [], []
    for W in Ws:
        q = quantize_hqq(W, 2, 64)
        c = build_compensator(W, q, 32, pad_to=64)
        t = (jnp.asarray(to_container(q.codes, 2)), jnp.asarray(q.scale), jnp.asarray(q.zero))
        args_q += list(t)
        args_w.append(t)
        args_c.append((
            jnp.asarray(to_container(c.u_q.codes, 3)), jnp.asarray(c.u_q.scale), jnp.asarray(c.u_q.zero),
            jnp.asarray(to_container(c.v_q.codes, 3)), jnp.asarray(c.v_q.scale), jnp.asarray(c.v_q.zero),
        ))

    y_q = expert_quant(x, *args_q, cbits=2, group_size=64, d_ff=f, d_out=d)
    y_c = expert_quant_comp(
        x, *args_w, *args_c, cbits=2, group_size=64, d_ff=f, d_out=d, rank=64
    )
    err_q = float(jnp.linalg.norm(y_q - y_true))
    err_c = float(jnp.linalg.norm(y_c - y_true))
    assert err_c < err_q, f"compensation must help: {err_c} vs {err_q}"


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    h=st.sampled_from([1, 4]),
    s=st.sampled_from([16, 64]),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31),
)
def test_decode_attention_matches_ref(b, h, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, s, dh)).astype(np.float32))
    lens = jnp.asarray(rng.integers(1, s + 1, size=(b,)).astype(np.int32))
    np.testing.assert_allclose(
        decode_attention(q, k, v, lens),
        ref_decode_attention(q, k, v, lens),
        atol=1e-4,
    )


def test_decode_attention_masks_stale_cache():
    """Rows past `lengths` must not affect output (slot-reuse invariant)."""
    rng = np.random.default_rng(13)
    b, h, s, dh = 2, 2, 32, 16
    q = jnp.asarray(rng.normal(size=(b, h, dh)).astype(np.float32))
    k = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    v = rng.normal(size=(b, h, s, dh)).astype(np.float32)
    lens = jnp.asarray(np.array([5, 9], dtype=np.int32))
    out1 = decode_attention(q, jnp.asarray(k), jnp.asarray(v), lens)
    k2, v2 = k.copy(), v.copy()
    k2[:, :, 20:] = 99.0  # garbage beyond the valid prefix
    v2[:, :, 20:] = -99.0
    out2 = decode_attention(q, jnp.asarray(k2), jnp.asarray(v2), lens)
    np.testing.assert_allclose(out1, out2, atol=1e-6)

"""BEAMW container: round-trips, format pinning (the rust reader mirrors this)."""

import numpy as np
import pytest

from compile import beamw


def test_roundtrip_all_dtypes(tmp_path):
    tensors = {
        "a.f32": np.random.default_rng(0).normal(size=(4, 5)).astype(np.float32),
        "b.i32": np.arange(12, dtype=np.int32).reshape(3, 4),
        "c.u8": np.arange(256, dtype=np.uint8).reshape(16, 16),
        "d.i8": (np.arange(16, dtype=np.int8) - 8).reshape(4, 4),
        "scalarish": np.array([3.5], dtype=np.float32),
    }
    path = tmp_path / "t.beamw"
    beamw.write(path, tensors)
    out = beamw.read(path)
    assert set(out) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_magic_pinned(tmp_path):
    path = tmp_path / "t.beamw"
    beamw.write(path, {"x": np.zeros(1, dtype=np.float32)})
    with open(path, "rb") as f:
        assert f.read(8) == b"BEAMW001"


def test_bad_magic_rejected(tmp_path):
    path = tmp_path / "bad.beamw"
    path.write_bytes(b"NOTBEAMW" + b"\x00" * 32)
    with pytest.raises(ValueError):
        beamw.read(path)


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(TypeError):
        beamw.write(tmp_path / "x.beamw", {"x": np.zeros(1, dtype=np.float64)})


def test_offsets_contiguous(tmp_path):
    """Tensors are laid out back-to-back (the rust reader assumes bounds)."""
    import json

    path = tmp_path / "t.beamw"
    beamw.write(
        path,
        {"a": np.zeros((2, 2), np.float32), "b": np.zeros(3, np.uint8)},
    )
    raw = path.read_bytes()
    hlen = int.from_bytes(raw[8:16], "little")
    header = json.loads(raw[16 : 16 + hlen])
    ends = 0
    for e in header["tensors"]:
        assert e["offset"] == ends
        ends += e["nbytes"]
    assert len(raw) == 16 + hlen + ends

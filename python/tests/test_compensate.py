"""Compensation pipeline tests: kurtosis, rank allocation, residual SVD."""

import numpy as np
import pytest

from compile.compensate import (
    allocate_ranks,
    allocate_uniform,
    build_compensator,
    build_compensator_from_svd,
    compensated_weight,
    kurtosis,
    residual_curve,
)
from compile.quant import dequantize, quantize_hqq


def test_kurtosis_gaussian_near_3():
    W = np.random.default_rng(0).normal(size=(256, 256))
    assert 2.8 < kurtosis(W) < 3.2


def test_kurtosis_heavy_tail_above_gaussian():
    rng = np.random.default_rng(1)
    heavy = rng.standard_t(df=3, size=(256, 256))
    assert kurtosis(heavy) > 4.0


def test_kurtosis_uniform_below_gaussian():
    W = np.random.default_rng(2).uniform(-1, 1, size=(128, 128))
    assert kurtosis(W) < 2.0


def test_kurtosis_constant_is_zero():
    assert kurtosis(np.full((32, 32), 7.0)) == 0.0


def test_allocate_respects_budget():
    rng = np.random.default_rng(3)
    k = rng.uniform(2, 50, size=96)
    for r_avg in (4, 8, 16, 32):
        ranks = allocate_ranks(k, r_avg, (0, 4, 8, 16, 32, 64), max_rank=128)
        assert ranks.sum() <= 96 * r_avg


def test_allocate_prioritizes_high_kurtosis():
    k = np.array([1.0, 10.0, 5.0, 2.0])
    ranks = allocate_ranks(k, 4, (0, 4, 8, 16), max_rank=128)
    assert ranks[1] >= ranks[2] >= ranks[3] >= ranks[0] or ranks[1] == ranks.max()
    assert ranks[1] == ranks.max()


def test_allocate_clamps_to_max_rank():
    k = np.array([10.0, 1.0])
    ranks = allocate_ranks(k, 512, (0, 16, 1024), max_rank=64)
    assert ranks.max() <= 16  # 1024 bucket infeasible under max_rank 64


def test_allocate_deterministic_on_ties():
    k = np.ones(8)
    a = allocate_ranks(k, 8, (0, 16, 32))
    b = allocate_ranks(k, 8, (0, 16, 32))
    np.testing.assert_array_equal(a, b)


def test_allocate_uniform():
    np.testing.assert_array_equal(allocate_uniform(4, 8), [8, 8, 8, 8])


@pytest.fixture(scope="module")
def quantized():
    rng = np.random.default_rng(4)
    # Column-scaled weights: spiked residual spectrum (DESIGN.md §3).
    W = rng.normal(size=(128, 256)).astype(np.float32)
    W *= np.exp(rng.normal(size=(1, 256)) * 0.8).astype(np.float32)
    q = quantize_hqq(W, 2, 64)
    return W, q


def test_compensator_reduces_residual(quantized):
    W, q = quantized
    base = np.linalg.norm(W - dequantize(q))
    for rank in (8, 16, 32):
        c = build_compensator(W, q, rank)
        err = np.linalg.norm(W - compensated_weight(q, c))
        assert err < base
    c8 = build_compensator(W, q, 8)
    c32 = build_compensator(W, q, 32)
    e8 = np.linalg.norm(W - compensated_weight(q, c8))
    e32 = np.linalg.norm(W - compensated_weight(q, c32))
    assert e32 < e8


def test_rank_zero_compensator(quantized):
    W, q = quantized
    c = build_compensator(W, q, 0)
    assert c.rank == 0
    assert c.transfer_nbytes() == 0
    np.testing.assert_array_equal(compensated_weight(q, c), dequantize(q))


def test_padding_columns_are_exact_zero(quantized):
    W, q = quantized
    c = build_compensator(W, q, 8, pad_to=64)
    u, v = c.factors()
    assert u.shape == (128, 64)
    assert v.shape == (64, 256)
    assert np.abs(u[:, 8:]).max() == 0.0
    assert np.abs(v[8:, :]).max() == 0.0


def test_padded_equals_unpadded_delta(quantized):
    W, q = quantized
    plain = build_compensator(W, q, 8)
    padded = build_compensator(W, q, 8, pad_to=64)
    np.testing.assert_allclose(plain.delta(), padded.delta(), atol=1e-4)


def test_pad_to_smaller_than_rank_raises(quantized):
    W, q = quantized
    with pytest.raises(ValueError):
        build_compensator(W, q, 32, pad_to=16)


def test_transfer_bytes_monotone_in_rank(quantized):
    W, q = quantized
    sizes = [build_compensator(W, q, r, pad_to=64).transfer_nbytes() for r in (4, 8, 16, 32)]
    assert sizes == sorted(sizes)
    assert all(s > 0 for s in sizes)


def test_transfer_bytes_independent_of_padding(quantized):
    W, q = quantized
    a = build_compensator(W, q, 8).transfer_nbytes()
    b = build_compensator(W, q, 8, pad_to=64).transfer_nbytes()
    assert a == b  # padding never crosses the wire


def test_compensator_cheaper_than_requantizing(quantized):
    """The whole point: rank-8 factors ≪ one INT2 expert matrix."""
    W, q = quantized
    c = build_compensator(W, q, 8)
    int2_matrix_bytes = W.size * 2 // 8
    assert c.transfer_nbytes() < int2_matrix_bytes / 2


def test_residual_curve_monotone(quantized):
    W, q = quantized
    curve = residual_curve(W, q, [0, 4, 8, 16, 32, 64, 128])
    assert all(a >= b - 1e-12 for a, b in zip(curve, curve[1:]))
    assert curve[-1] < curve[0]


def test_from_svd_matches_direct(quantized):
    W, q = quantized
    E = W - dequantize(q)
    svd = np.linalg.svd(E.astype(np.float64), full_matrices=False)
    a = build_compensator(W, q, 16)
    b = build_compensator_from_svd(svd, 16)
    np.testing.assert_allclose(a.delta(), b.delta(), atol=1e-5)

"""Artifact-dependent tests (skipped until `make artifacts` has run).

These validate the *shipped* artifacts: manifest consistency, weight-store
completeness, eval-variant ordering (the Fig. 6 shape), and router skew
(the Fig. 3 premise).
"""

import json
import pathlib

import numpy as np
import pytest

from compile import beamw
from compile.model import CONFIGS

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "mixtral-tiny" / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "mixtral-tiny" / "manifest.json").read_text())


@pytest.fixture(scope="module")
def store():
    return beamw.read(ART / "mixtral-tiny" / "weights.beamw")


def test_manifest_model_matches_config(manifest):
    cfg = CONFIGS["mixtral-tiny"]
    m = manifest["model"]
    assert m["d_model"] == cfg.d_model
    assert m["n_experts"] == cfg.n_experts
    assert m["top_k"] == cfg.top_k


def test_all_stage_files_exist(manifest):
    for name, entry in manifest["stages"].items():
        assert (ART / "mixtral-tiny" / entry["file"]).exists(), name


def test_store_has_every_expert_variant(manifest, store):
    m = manifest["model"]
    for li in range(m["n_layers"]):
        for e in range(m["n_experts"]):
            for proj in ("w1", "w2", "w3"):
                base = f"layers.{li}.experts.{e}.{proj}"
                assert f"{base}.fp32" in store
                for b in manifest["quant"]["bits"]:
                    for method in manifest["quant"]["methods"]:
                        assert f"{base}.{method}{b}.pk" in store
                for b in manifest["quant"]["comp_bits"]:
                    assert f"{base}.comp{b}.default.up" in store


def test_transfer_bytes_ordering(manifest):
    t = manifest["transfer"]
    q = {int(k): v for k, v in t["q_expert_bytes"].items()}
    assert q[2] < q[3] < q[4] < t["fp16_expert_bytes"]


def test_comp_bytes_small_vs_expert(manifest):
    """Compensators must be a small fraction of even an INT2 expert."""
    t = manifest["transfer"]
    comp = np.array(t["comp_bytes"]["default"]["2"], dtype=float)
    assert comp.mean() < 0.6 * t["q_expert_bytes"]["2"]


def test_rank_table_budget(manifest):
    m = manifest["model"]
    ranks = manifest["rank_table"]["default"]["ranks"]
    assert len(ranks) == len(manifest["mat_keys"])
    assert np.mean(ranks) <= m["r_avg"] + 1e-9


def test_router_skew(manifest):
    """Fig. 3 premise: rank-0 score dominates rank-1 for the mixtral-style model."""
    stats = json.loads((ART / "mixtral-tiny" / "router_stats.json").read_text())
    mean = stats["mean_over_layers"]
    assert mean[0] > 1.5 * mean[1]


def test_deepseek_router_flatter():
    mx = json.loads((ART / "mixtral-tiny" / "router_stats.json").read_text())
    ds = json.loads((ART / "deepseek-tiny" / "router_stats.json").read_text())
    assert ds["mean_over_layers"][0] < mx["mean_over_layers"][0]


def test_kurtosis_error_correlation_positive():
    """Fig. 4b: kurtosis correlates with INT2 quantization error."""
    entries = json.loads((ART / "mixtral-tiny" / "kurtosis.json").read_text())
    k = np.log([e["kurtosis"] for e in entries])
    err = np.array([e["err"]["2"] for e in entries])
    corr = np.corrcoef(k, err)[0, 1]
    assert corr > 0.1, corr


@pytest.mark.slow
def test_eval_variant_ordering():
    """Fig. 6 shape on a small subset: fp16 ≤ ours2 ≤ hqq2 (ppl)."""
    from compile.eval import evaluate_variant
    from compile.model import MIXTRAL_TINY

    res = {
        v: evaluate_variant(MIXTRAL_TINY, ART, v, max_seqs=24)["ppl"]
        for v in ("fp16", "ours2", "hqq2")
    }
    assert res["fp16"] <= res["ours2"] + 1e-6
    assert res["ours2"] <= res["hqq2"] * 1.02

"""L2 model tests: primitives, training forward, stage/training parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    CONFIGS,
    MIXTRAL_TINY,
    ModelConfig,
    forward_train,
    init_params,
    rmsnorm,
    rope,
    router_probs,
    stage_attn_prefill,
    stage_embed,
    stage_head,
    stage_router,
    topk_mask_renorm,
)

TINY = ModelConfig(
    name="unit", vocab=64, d_model=64, d_ff=128, n_layers=2, n_heads=2,
    n_experts=4, top_k=2, s_max=32, t_prefill=16, b_max=2,
)


@pytest.fixture(scope="module")
def params():
    return init_params(TINY, jax.random.PRNGKey(0))


def test_init_shapes(params):
    assert params["emb"].shape == (64, 64)
    layer = params["layers"][0]
    assert layer["w1"].shape == (4, 64, 128)
    assert layer["gate"].shape == (4,)[0:0] or layer["gate"].shape == (64, 4)


def test_init_outlier_heterogeneity(params):
    """Per-expert kurtosis must vary (drives the rank allocator)."""
    from compile.compensate import kurtosis

    ks = [kurtosis(np.asarray(params["layers"][0]["w1"][e])) for e in range(4)]
    assert max(ks) > min(ks) * 1.5


def test_rmsnorm_unit_scale():
    x = jnp.array([[3.0, 4.0]])
    out = rmsnorm(x, jnp.ones(2))
    np.testing.assert_allclose(
        np.asarray(jnp.mean(out**2, -1)), 1.0, rtol=1e-4
    )


def test_rope_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    pos = jnp.arange(4)
    out = rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1),
        rtol=1e-5,
    )


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8))
    out = rope(x, jnp.zeros(1), 10000.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


def test_router_probs_normalized(params):
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 64))
    p = router_probs(x, params["layers"][0]["gate"])
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)


def test_topk_mask_renorm_properties():
    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(4), (7, 8)))
    w = topk_mask_renorm(p, 2)
    w_np = np.asarray(w)
    assert ((w_np > 0).sum(-1) == 2).all()
    np.testing.assert_allclose(w_np.sum(-1), 1.0, rtol=1e-5)
    # nonzero entries correspond to the top-2 probs
    for row_p, row_w in zip(np.asarray(p), w_np):
        top2 = set(np.argsort(-row_p)[:2])
        assert set(np.nonzero(row_w)[0]) == top2


def test_forward_train_shapes_and_finite(params):
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, 64, size=(2, 16), dtype=np.int32)
    )
    logits, aux = forward_train(TINY, params, tokens)
    assert logits.shape == (2, 16, 64)
    assert np.isfinite(np.asarray(logits)).all()
    assert float(aux) >= 1.0 - 1e-3  # switch loss lower bound at E·Σf·p = 1


def test_forward_train_causality(params):
    """Changing a future token must not affect earlier logits."""
    rng = np.random.default_rng(5)
    t1 = rng.integers(0, 64, size=(1, 12), dtype=np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 7) % 64
    l1, _ = forward_train(TINY, params, jnp.asarray(t1))
    l2, _ = forward_train(TINY, params, jnp.asarray(t2))
    np.testing.assert_allclose(
        np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-5
    )


def test_stage_parity_with_training_forward(params):
    """The staged serving path (prefill stages + dense top-k combine) must
    match `forward_train` — pins the L2/L3 execution semantics."""
    cfg = TINY
    rng = np.random.default_rng(6)
    T = cfg.t_prefill
    tokens = rng.integers(0, cfg.vocab, size=(T,), dtype=np.int32)

    # Reference: training forward.
    ref_logits, _ = forward_train(cfg, params, jnp.asarray(tokens[None, :]))
    ref_logits = np.asarray(ref_logits[0])

    # Staged path.
    (x,) = stage_embed(jnp.asarray(tokens), params["emb"])
    attn = stage_attn_prefill(cfg)
    for layer in params["layers"]:
        x2, _, _ = attn(x, layer["ln1"], layer["wq"], layer["wk"], layer["wv"], layer["wo"])
        xn, probs = stage_router(x2, layer["ln2"], layer["gate"])
        w = topk_mask_renorm(probs, cfg.top_k)
        # dense expert eval with stage semantics (fp16 experts)
        moe = jnp.zeros_like(x2)
        for e in range(cfg.n_experts):
            from compile.kernels import expert_fp16

            y = expert_fp16(xn, layer["w1"][e], layer["w2"][e], layer["w3"][e])
            moe = moe + w[:, e : e + 1] * y
        x = x2 + moe
    (logits,) = stage_head(x, params["ln_f"], params["emb"])
    np.testing.assert_allclose(np.asarray(logits), ref_logits, atol=2e-3, rtol=1e-3)


def test_configs_registered():
    assert "mixtral-tiny" in CONFIGS
    assert "deepseek-tiny" in CONFIGS
    assert CONFIGS["deepseek-tiny"].n_shared == 1
    assert MIXTRAL_TINY.top_n < MIXTRAL_TINY.top_k

"""Quantizer tests: uniform RTN, HQQ optimization, GPTQ error feedback."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quant import dequantize, quantize_gptq, quantize_hqq, quantize_uniform
from compile.quant.uniform import quantize_with_params, relative_residual_fro


def rand_w(seed=0, shape=(128, 64)):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@pytest.mark.parametrize("bits", [2, 3, 4, 8])
def test_uniform_codes_in_range(bits):
    q = quantize_uniform(rand_w(), bits, 64)
    assert q.codes.dtype == np.uint8
    assert q.codes.max() <= 2**bits - 1


@pytest.mark.parametrize("bits,bound", [(2, 0.60), (3, 0.30), (4, 0.15), (8, 0.01)])
def test_uniform_error_bounds(bits, bound):
    W = rand_w()
    q = quantize_uniform(W, bits, 64)
    assert relative_residual_fro(W, q) < bound


def test_uniform_error_decreases_with_bits():
    W = rand_w(1)
    errs = [relative_residual_fro(W, quantize_uniform(W, b, 64)) for b in (2, 3, 4, 8)]
    assert errs == sorted(errs, reverse=True)


def test_uniform_exact_on_degenerate_groups():
    W = np.full((64, 8), 3.25, dtype=np.float32)
    q = quantize_uniform(W, 2, 64)
    np.testing.assert_allclose(dequantize(q), W, atol=1e-6)


def test_group_structure():
    W = rand_w(2, (128, 32))
    q = quantize_uniform(W, 4, 64)
    assert q.scale.shape == (2, 32)
    assert q.zero.shape == (2, 32)


def test_group_size_must_divide():
    with pytest.raises(ValueError):
        quantize_uniform(rand_w(0, (100, 8)), 4, 64)


def test_quantize_with_params_matches_roundtrip():
    W = rand_w(3)
    q = quantize_uniform(W, 3, 64)
    codes2 = quantize_with_params(W, q.scale, q.zero, 3, 64)
    np.testing.assert_array_equal(q.codes, codes2)


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31),
    cols=st.integers(4, 32),
)
def test_hqq_never_much_worse_than_rtn(bits, seed, cols):
    W = rand_w(seed, (128, cols))
    e_rtn = relative_residual_fro(W, quantize_uniform(W, bits, 64))
    e_hqq = relative_residual_fro(W, quantize_hqq(W, bits, 64))
    assert e_hqq <= e_rtn * 1.02


def test_hqq_improves_on_heavy_tails():
    rng = np.random.default_rng(0)
    W = rng.standard_t(df=3, size=(128, 64)).astype(np.float32)
    e_rtn = relative_residual_fro(W, quantize_uniform(W, 2, 64))
    e_hqq = relative_residual_fro(W, quantize_hqq(W, 2, 64))
    assert e_hqq < e_rtn


def test_hqq_metadata_shapes_match_uniform():
    W = rand_w(5)
    qu, qh = quantize_uniform(W, 2, 64), quantize_hqq(W, 2, 64)
    assert qh.scale.shape == qu.scale.shape
    assert qh.zero.shape == qu.zero.shape
    assert qh.codes.max() <= 3


def _calib(seed, n, d):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def test_gptq_beats_rtn_in_proxy_loss():
    """GPTQ minimizes ||X W − X Ŵ||_F, not weight error — check that."""
    rng = np.random.default_rng(7)
    W = rng.normal(size=(128, 32)).astype(np.float32)
    # Correlated calibration inputs (GPTQ's advantage shows under correlation).
    base = rng.normal(size=(512, 16))
    X = (base @ rng.normal(size=(16, 128)) + 0.1 * rng.normal(size=(512, 128))).astype(
        np.float32
    )
    q_rtn = quantize_uniform(W, 3, 64)
    q_gptq = quantize_gptq(W, X, 3, 64)

    def proxy(q):
        return float(np.linalg.norm(X @ W - X @ dequantize(q)))

    assert proxy(q_gptq) < proxy(q_rtn)


def test_gptq_codes_valid():
    W = rand_w(8, (128, 16))
    q = quantize_gptq(W, _calib(0, 256, 128), 2, 64)
    assert q.codes.max() <= 3
    assert q.scale.shape == (2, 16)


def test_gptq_handles_dead_inputs():
    W = rand_w(9, (128, 8))
    X = _calib(1, 256, 128)
    X[:, 5] = 0.0  # dead input channel
    q = quantize_gptq(W, X, 4, 64)
    assert np.isfinite(dequantize(q)).all()

"""Accuracy evaluation of quantization variants (build-time oracle).

Evaluates held-out perplexity and cloze accuracy (DESIGN.md §3: stand-ins
for WikiText PPL and the commonsense/MMLU suites) for every quantization
variant the paper's Fig. 6 / Fig. 8 / Table 2 compare:

* ``fp16``            — unquantized experts (upper bound)
* ``hqq{2,3,4}``      — uniform HQQ, no compensation
* ``gptq{2,3,4}``     — uniform GPTQ baseline
* ``ours{2,3}[:tag[:positions]]`` — HQQ + router-guided low-rank restore.
  ``tag`` picks the compensator set (``default``, ``r8k``, ``r8u`` …);
  ``positions`` is the restored router-rank set, e.g. ``0`` (top-1), ``0-2``
  (top-3), ``1`` (ONLY the 2nd-ranked expert — Table 2), ``3-5``.

The "ours" forward computes both the quantized and the compensated output of
every activated expert and selects per (token, expert) according to the
router rank — exactly the semantics the rust coordinator implements with
selective transfers; the two paths are pinned against each other by
integration tests.  The rust `figure fig6` harness regenerates these numbers
via staged PJRT execution; this module is the fast full-set oracle.

Usage:  python -m compile.eval mixtral-tiny fp16 hqq2 ours2 …  (from python/)
"""

from __future__ import annotations

import functools
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import beamw
from .corpus import SyntheticCorpus
from .model import CONFIGS, ModelConfig, rmsnorm, rope, router_probs
from .quant.packing import unpack_codes
from .train import unflatten_params

V_GROUP = 4


# --------------------------------------------------------------------------
# Variant weight reconstruction from artifacts
# --------------------------------------------------------------------------

def _dequant_from_store(t, prefix: str, cbits: int, n_out: int, group: int):
    codes = unpack_codes(t[f"{prefix}.pk"], cbits, n_out)
    scale, zero = t[f"{prefix}.sc"], t[f"{prefix}.zp"]
    g = codes.shape[0] // group
    deq = (codes.astype(np.float32).reshape(g, group, n_out) - zero[:, None, :]) * scale[:, None, :]
    return deq.reshape(codes.shape)


def _comp_delta_from_store(t, prefix: str, rank_pad: int, d_in: int, d_out: int):
    u = _dequant_from_store_factor(t, prefix, "u", rank_pad, d_in)
    v = _dequant_from_store_factor(t, prefix, "v", d_out, rank_pad)
    return u @ v


def _dequant_from_store_factor(t, prefix, which, n_out, d_in):
    codes = unpack_codes(t[f"{prefix}.{which}p"], 4, n_out)
    scale, zero = t[f"{prefix}.{which}s"], t[f"{prefix}.{which}z"]
    group = d_in // scale.shape[0]
    g = scale.shape[0]
    deq = (codes.astype(np.float32).reshape(g, group, n_out) - zero[:, None, :]) * scale[:, None, :]
    return deq.reshape(d_in, n_out)


def load_variant_weights(
    cfg: ModelConfig, tensors: dict, manifest: dict, variant: str
):
    """Returns (expert_weights, comp_deltas, positions).

    expert_weights: per layer, dict proj -> (E, d_in, d_out) float32.
    comp_deltas: same shape or None (uniform variants / fp16).
    positions: sorted router-rank positions to restore, or None.
    """
    cb = manifest["quant"]["container_bits"]
    g = cfg.group_size
    d, f = cfg.d_model, cfg.d_ff
    dims = {"w1": (d, f), "w2": (f, d), "w3": (d, f)}

    parts = variant.split(":")
    name = parts[0]
    comp_tag, positions = None, None
    if name == "fp16":
        method = "fp32"
    elif name.startswith("ours"):
        bits = int(name[4:])
        method = f"hqq{bits}"
        comp_tag = parts[1] if len(parts) > 1 else "default"
        pos_spec = parts[2] if len(parts) > 2 else f"0-{cfg.top_n - 1}"
        if "-" in pos_spec:
            lo, hi = pos_spec.split("-")
            positions = list(range(int(lo), int(hi) + 1))
        else:
            positions = [int(pos_spec)]
    else:
        method = name  # hqq{b} / gptq{b}

    weights, deltas = [], []
    for li in range(cfg.n_layers):
        wl, dl = {}, {}
        for proj, (d_in, d_out) in dims.items():
            mats, dmats = [], []
            for e in range(cfg.n_experts):
                base = f"layers.{li}.experts.{e}.{proj}"
                if method == "fp32":
                    mats.append(tensors[f"{base}.fp32"])
                else:
                    bits = int(method[-1])
                    mats.append(
                        _dequant_from_store(t=tensors, prefix=f"{base}.{method}",
                                            cbits=cb[str(bits)], n_out=d_out, group=g)
                    )
                if comp_tag is not None:
                    bits = int(method[-1])
                    dmats.append(
                        _comp_delta_from_store(
                            tensors, f"{base}.comp{bits}.{comp_tag}",
                            cfg.rank_pad, d_in, d_out,
                        )
                    )
            wl[proj] = np.stack(mats)
            if dmats:
                dl[proj] = np.stack(dmats)
        weights.append(wl)
        deltas.append(dl if dl else None)
    return weights, (deltas if comp_tag else None), positions


# --------------------------------------------------------------------------
# Variant forward (dense experts + per-token compensation selection)
# --------------------------------------------------------------------------

def forward_variant(
    cfg: ModelConfig,
    params,
    expert_weights,
    comp_deltas,
    positions,
    tokens: jnp.ndarray,
):
    """Teacher-forced forward with substituted expert weights.

    Attention / router / norms run at full precision (only experts are
    offloaded+quantized in the paper).  When ``comp_deltas`` is given, a
    (token, expert) pair uses the compensated weights iff the expert's
    router *rank* for that token is in ``positions``.
    """
    b, t = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]
    pos = jnp.arange(t)
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))

    for li, layer in enumerate(params["layers"]):
        xn = rmsnorm(x, layer["ln1"])
        q = rope((xn @ layer["wq"]).reshape(b, t, h, dh), pos[None, :, None], cfg.rope_theta)
        k = rope((xn @ layer["wk"]).reshape(b, t, h, dh), pos[None, :, None], cfg.rope_theta)
        v = (xn @ layer["wv"]).reshape(b, t, h, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        attn = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, axis=-1), v)
        x = x + attn.reshape(b, t, d) @ layer["wo"]

        xn = rmsnorm(x, layer["ln2"])
        probs = router_probs(xn, layer["gate"])  # (B,T,E)
        top_vals = jax.lax.top_k(probs, cfg.top_k)[0]
        w = jnp.where(probs >= top_vals[..., -1:], probs, 0.0)
        w = w / jnp.sum(w, axis=-1, keepdims=True)

        ew = expert_weights[li]

        def expert_out(w1, w2, w3):
            gh = jnp.einsum("btd,edf->ebtf", xn, w1)
            uh = jnp.einsum("btd,edf->ebtf", xn, w3)
            return jnp.einsum("ebtf,efd->ebtd", jax.nn.silu(gh) * uh, w2)

        y_q = expert_out(
            jnp.asarray(ew["w1"]), jnp.asarray(ew["w2"]), jnp.asarray(ew["w3"])
        )
        if comp_deltas is not None:
            cd = comp_deltas[li]
            y_c = expert_out(
                jnp.asarray(ew["w1"] + cd["w1"]),
                jnp.asarray(ew["w2"] + cd["w2"]),
                jnp.asarray(ew["w3"] + cd["w3"]),
            )
            # Router rank of each expert per token: rank[b,t,e] ∈ [0, E).
            order = jnp.argsort(-probs, axis=-1)
            rank = jnp.argsort(order, axis=-1)
            restore = jnp.zeros(probs.shape, bool)
            for p in positions:
                restore = restore | (rank == p)
            # Restoration only matters for *activated* experts (w > 0);
            # non-selected experts contribute nothing either way.
            y_sel = jnp.where(restore.transpose(2, 0, 1)[..., None], y_c, y_q)
        else:
            y_sel = y_q
        moe = jnp.einsum("bte,ebtd->btd", w, y_sel)
        if cfg.n_shared:
            sg = jnp.einsum("btd,edf->ebtf", xn, layer["sw1"])
            su = jnp.einsum("btd,edf->ebtf", xn, layer["sw3"])
            moe = moe + jnp.einsum("ebtf,efd->btd", jax.nn.silu(sg) * su, layer["sw2"])
        x = x + moe

    return rmsnorm(x, params["ln_f"]) @ params["emb"].T


# --------------------------------------------------------------------------
# Metrics
# --------------------------------------------------------------------------

def evaluate_variant(
    cfg: ModelConfig,
    artifacts: pathlib.Path,
    variant: str,
    max_seqs: int | None = None,
) -> dict:
    tensors = beamw.read(artifacts / cfg.name / "weights.beamw")
    manifest = json.loads((artifacts / cfg.name / "manifest.json").read_text())
    evald = beamw.read(artifacts / cfg.name / "eval.beamw")
    flat = dict(np.load(artifacts / cfg.name / "weights_fp32.npz"))
    params = unflatten_params(cfg, flat)

    weights, deltas, positions = load_variant_weights(cfg, tensors, manifest, variant)
    tokens = evald["val_tokens"]
    det = evald["val_det"]
    if max_seqs:
        tokens, det = tokens[:max_seqs], det[:max_seqs]

    fwd = jax.jit(
        lambda toks: forward_variant(cfg, params, weights, deltas, positions, toks)
    )

    nll_sum, nll_n, cloze_hit, cloze_n = 0.0, 0, 0, 0
    bs = 32
    for i in range(0, tokens.shape[0], bs):
        tb = jnp.asarray(tokens[i : i + bs])
        db = det[i : i + bs]
        logits = np.asarray(fwd(tb))
        logp = logits - np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1, keepdims=True)) - logits.max(-1, keepdims=True)
        tgt = tokens[i : i + bs, 1:]
        lp = np.take_along_axis(logp[:, :-1], tgt[..., None], axis=-1)[..., 0]
        nll_sum += float(-lp.sum())
        nll_n += lp.size
        pred = logits[:, :-1].argmax(-1)
        mask = db[:, 1:] > 0
        cloze_hit += int(((pred == tgt) & mask).sum())
        cloze_n += int(mask.sum())

    return {
        "model": cfg.name,
        "variant": variant,
        "ppl": float(np.exp(nll_sum / nll_n)),
        "cloze_acc": cloze_hit / max(cloze_n, 1),
        "n_seqs": int(tokens.shape[0]),
    }


def main():
    args = sys.argv[1:]
    model = args[0]
    variants = args[1:] or ["fp16", "hqq2", "hqq3", "ours2", "ours3"]
    cfg = CONFIGS[model]
    artifacts = pathlib.Path("../artifacts")
    for v in variants:
        r = evaluate_variant(cfg, artifacts, v)
        print(json.dumps(r))


if __name__ == "__main__":
    main()

"""L2 — the MoE transformer LM, in JAX, calling the L1 pallas kernels.

Two faces of the same model:

* **training forward** (`forward_train`) — full-sequence, pure-jnp, fp32,
  dense-expert evaluation with top-k masking + load-balancing aux loss.
  Used by `train.py` only; nothing here is exported.

* **inference stages** (`stage_*`) — the per-step functions the rust
  coordinator drives.  Each is shape-static, takes *weights as parameters*
  (so one compiled executable serves every layer / expert / slot), and is
  lowered to HLO text by `aot.py`.  The decode/prefill hot spots call the
  pallas kernels from `kernels/`.

The decomposition boundary is the paper's: the router's scores leave the
graph and return to rust (L3) where the top-k / top-n *policy* decisions
live, so changing the compensation policy never re-lowers anything.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import (
    decode_attention,
    expert_fp16,
    expert_quant,
    expert_quant_comp,
)

RMS_EPS = 1e-5


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (Table 1 analogue, DESIGN.md §3)."""

    name: str
    vocab: int = 512
    d_model: int = 128
    d_ff: int = 256
    n_layers: int = 4
    n_heads: int = 4
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0  # DeepSeek-style always-on experts
    s_max: int = 320  # prefill 256 + decode 64
    t_prefill: int = 256
    b_max: int = 8
    rope_theta: float = 10000.0
    # Quant/compensation defaults (paper §4.2 configuration paragraph).
    group_size: int = 64
    rank_pad: int = 64  # executable rank (pad_to)
    rank_buckets: tuple = (0, 4, 8, 16, 32, 64)
    r_avg: int = 8
    top_n: int = 1  # experts compensated per token

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


MIXTRAL_TINY = ModelConfig(
    name="mixtral-tiny", n_experts=8, top_k=2, n_shared=0, r_avg=8, top_n=1
)
DEEPSEEK_TINY = ModelConfig(
    name="deepseek-tiny", n_experts=16, top_k=4, n_shared=1, r_avg=16, top_n=3
)
CONFIGS = {c.name: c for c in (MIXTRAL_TINY, DEEPSEEK_TINY)}


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    """Initialise fp32 parameters (fan-in-scaled normal).

    Expert weights additionally receive **per-output-channel outlier scales**
    (log-normal, with per-expert strength): production MoE experts are
    heavy-tailed with a few dominant channels (paper Fig. 4b; KurTail), and
    that structure — not trainable into a tiny model in a few hundred steps —
    is exactly what makes quantization residuals *low-rank* (the error
    concentrates in the outlier columns, one near-rank-1 component each) and
    what spreads kurtosis across experts so the paper's rank allocation has
    signal.  DESIGN.md §3 records this substitution.  Training proceeds on
    top of the scaled init, so the final weights are still fully trained.
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def dense(key, shape, scale=None):
        fan_in = shape[-2] if len(shape) > 1 else shape[0]
        s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
        return jax.random.normal(key, shape, dtype=jnp.float32) * s

    def expert_stack(key, n, d_in, d_out):
        """(n, d_in, d_out) expert weights with per-expert outlier structure.

        Two ingredients, mirroring production-LLM weight statistics:
        * *entry-level heavy tails* — student-t base noise with per-expert
          degrees of freedom (df ∈ [4, 40]): low-df experts have high
          kurtosis and, because single large entries blow up their quant
          group's dynamic range, high relative quantization error — the
          Fig. 4b correlation.
        * *outlier output-channels* — log-normal per-column scales with
          per-expert strength: the quantization residual's absolute energy
          concentrates in the scaled columns (one near-rank-1 component
          each), giving the spiked spectrum low-rank compensation needs.
        """
        kw, kdf, ks, kstr = jax.random.split(key, 4)
        df = jax.random.uniform(kdf, (n, 1, 1), minval=4.0, maxval=40.0)
        t = jax.random.t(kw, df, (n, d_in, d_out), dtype=jnp.float32)
        # Normalize t to unit variance (var = df/(df-2)), then fan-in scale.
        w = t * jnp.sqrt((df - 2.0) / df) / np.sqrt(d_in)
        strength = jax.random.uniform(kstr, (n, 1, 1), minval=0.05, maxval=1.0)
        col_scales = jnp.exp(jax.random.normal(ks, (n, 1, d_out)) * strength)
        return w * col_scales

    keys = iter(jax.random.split(key, 8 + cfg.n_layers * 16))
    params = {
        "emb": dense(next(keys), (v, d), scale=0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": dense(next(keys), (d, d)),
            "wk": dense(next(keys), (d, d)),
            "wv": dense(next(keys), (d, d)),
            "wo": dense(next(keys), (d, d)),
            "ln2": jnp.ones((d,), jnp.float32),
            "gate": dense(next(keys), (d, cfg.n_experts)),
            "w1": expert_stack(next(keys), cfg.n_experts, d, f),
            "w2": expert_stack(next(keys), cfg.n_experts, f, d),
            "w3": expert_stack(next(keys), cfg.n_experts, d, f),
        }
        if cfg.n_shared:
            layer["sw1"] = dense(next(keys), (cfg.n_shared, d, f))
            layer["sw2"] = dense(next(keys), (cfg.n_shared, f, d))
            layer["sw3"] = dense(next(keys), (cfg.n_shared, d, f))
        params["layers"].append(layer)
    return params


# --------------------------------------------------------------------------
# Shared primitives
# --------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return x * w * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + RMS_EPS)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding; x (..., dh) with dh even, pos broadcastable to x[..., 0]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def router_probs(xn: jnp.ndarray, gate: jnp.ndarray) -> jnp.ndarray:
    """Full softmax over all experts (the paper's w_i = softmax(G(x))).

    Top-k selection and renormalization over the selected set (Mixtral
    convention) happen in L3 (rust) / in `forward_train` for training.
    """
    return jax.nn.softmax(xn @ gate, axis=-1)


def topk_mask_renorm(probs: jnp.ndarray, k: int) -> jnp.ndarray:
    """Zero all but the top-k probs per row, renormalize — the combine weights
    the rust coordinator reproduces bit-for-bit (pinned by integration tests)."""
    top_vals = jax.lax.top_k(probs, k)[0]
    thresh = top_vals[..., -1:]
    masked = jnp.where(probs >= thresh, probs, 0.0)
    return masked / jnp.sum(masked, axis=-1, keepdims=True)


# --------------------------------------------------------------------------
# Training forward (full-sequence, dense experts)
# --------------------------------------------------------------------------

def forward_train(cfg: ModelConfig, params: dict, tokens: jnp.ndarray):
    """Causal LM forward over (B, T) tokens -> (logits, aux_loss).

    Experts are evaluated densely and combined with top-k-masked router
    weights: numerically identical to the serving path (which simply skips
    zero-weight experts) and trivially differentiable.  Aux loss is the
    switch-transformer load-balancing term.
    """
    b, t = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]
    pos = jnp.arange(t)
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    aux = 0.0

    for layer in params["layers"]:
        xn = rmsnorm(x, layer["ln1"])
        q = rope((xn @ layer["wq"]).reshape(b, t, h, dh), pos[None, :, None], cfg.rope_theta)
        k = rope((xn @ layer["wk"]).reshape(b, t, h, dh), pos[None, :, None], cfg.rope_theta)
        v = (xn @ layer["wv"]).reshape(b, t, h, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        attn = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, axis=-1), v)
        x = x + attn.reshape(b, t, d) @ layer["wo"]

        xn = rmsnorm(x, layer["ln2"])
        probs = router_probs(xn, layer["gate"])  # (B, T, E)
        w = topk_mask_renorm(probs, cfg.top_k)
        gate_h = jnp.einsum("btd,edf->ebtf", xn, layer["w1"])
        up_h = jnp.einsum("btd,edf->ebtf", xn, layer["w3"])
        eh = jax.nn.silu(gate_h) * up_h
        ey = jnp.einsum("ebtf,efd->ebtd", eh, layer["w2"])
        moe = jnp.einsum("bte,ebtd->btd", w, ey)
        if cfg.n_shared:
            sg = jnp.einsum("btd,edf->ebtf", xn, layer["sw1"])
            su = jnp.einsum("btd,edf->ebtf", xn, layer["sw3"])
            moe = moe + jnp.einsum("ebtf,efd->btd", jax.nn.silu(sg) * su, layer["sw2"])
        x = x + moe

        sel = (w > 0).astype(jnp.float32)
        f_e = jnp.mean(sel, axis=(0, 1)) / cfg.top_k
        p_e = jnp.mean(probs, axis=(0, 1))
        aux = aux + cfg.n_experts * jnp.sum(f_e * p_e)

    logits = rmsnorm(x, params["ln_f"]) @ params["emb"].T
    return logits, aux / cfg.n_layers


# --------------------------------------------------------------------------
# Inference stages (AOT-exported; weights are *arguments*)
# --------------------------------------------------------------------------
# Every stage returns a tuple — aot.py lowers with return_tuple=True and the
# rust runtime unwraps with to_tuple{1,2,3}.

def stage_embed(tokens: jnp.ndarray, emb: jnp.ndarray):
    """tokens (N,) int32 -> hidden (N, d)."""
    return (emb[tokens],)


def stage_attn_decode(cfg: ModelConfig, use_pallas: bool = False):
    """Decode attention for B slots: one new token per slot.

    (x, ln1, wq, wk, wv, wo, k_cache, v_cache, pos) ->
        (x_out, k_cache', v_cache')
    caches (B, H, S, dh); pos (B,) int32 = write position per slot.
    Inactive slots must pass pos >= 0; the kernel masks reads past pos.
    """
    h, dh, theta = cfg.n_heads, cfg.d_head, cfg.rope_theta

    def fn(x, ln1, wq, wk, wv, wo, k_cache, v_cache, pos):
        b, d = x.shape
        xn = rmsnorm(x, ln1)
        q = rope((xn @ wq).reshape(b, h, dh), pos[:, None], theta)
        k = rope((xn @ wk).reshape(b, h, dh), pos[:, None], theta)
        v = (xn @ wv).reshape(b, h, dh)

        def write(cache, val):
            def one(c, vv, p):  # c (H,S,dh), vv (H,dh)
                return jax.lax.dynamic_update_slice(c, vv[:, None, :], (0, p, 0))

            return jax.vmap(one)(cache, val, pos)

        k_cache = write(k_cache, k)
        v_cache = write(v_cache, v)
        lengths = jnp.maximum(pos + 1, 1)
        if use_pallas:
            out = decode_attention(q, k_cache, v_cache, lengths)  # pallas kernel
        else:
            # Fused jnp attention — same math as the pallas kernel (pinned by
            # python/tests/test_kernels.py); the interpret-mode grid loop costs
            # ~15 ms/call on CPU-PJRT vs ~1 ms for the fused form, so the AOT
            # decode stage ships this path (EXPERIMENTS.md §Perf, L2 entry).
            # On real TPU the pallas kernel is the intended lowering.
            scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / np.sqrt(dh)
            mask = jnp.arange(k_cache.shape[2])[None, None, :] < lengths[:, None, None]
            probs = jax.nn.softmax(jnp.where(mask, scores, -jnp.inf), axis=-1)
            out = jnp.einsum("bhs,bhsd->bhd", probs, v_cache)
        return (x + out.reshape(b, d) @ wo, k_cache, v_cache)

    return fn


def stage_attn_prefill(cfg: ModelConfig):
    """Full causal attention over one sequence of T tokens (slot prefill).

    (x (T,d), ln1, wq, wk, wv, wo) -> (x_out (T,d), k_cache (H,S,dh), v_cache)
    Caches come back padded to s_max so rust can install them into the slot.
    Prompts shorter than T are right-padded by rust; causal masking keeps
    padding from contaminating the valid prefix.
    """
    h, dh, s_max, theta = cfg.n_heads, cfg.d_head, cfg.s_max, cfg.rope_theta

    def fn(x, ln1, wq, wk, wv, wo):
        t, d = x.shape
        xn = rmsnorm(x, ln1)
        pos = jnp.arange(t)
        q = rope((xn @ wq).reshape(t, h, dh), pos[:, None], theta)
        k = rope((xn @ wk).reshape(t, h, dh), pos[:, None], theta)
        v = (xn @ wv).reshape(t, h, dh)
        scores = jnp.einsum("qhd,khd->hqk", q, k) / np.sqrt(dh)
        causal = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(causal[None], scores, -jnp.inf)
        attn = jnp.einsum("hqk,khd->qhd", jax.nn.softmax(scores, axis=-1), v)
        out = x + attn.reshape(t, d) @ wo
        kc = jnp.zeros((h, s_max, dh), jnp.float32).at[:, :t, :].set(k.transpose(1, 0, 2))
        vc = jnp.zeros((h, s_max, dh), jnp.float32).at[:, :t, :].set(v.transpose(1, 0, 2))
        return (out, kc, vc)

    return fn


def stage_router(x: jnp.ndarray, ln2: jnp.ndarray, gate: jnp.ndarray):
    """(x (N,d), ln2, gate) -> (xn (N,d), probs (N,E)).

    xn is returned so expert stages receive the normed input without
    re-doing the norm; probs feed the L3 top-k/top-n policy.
    """
    xn = rmsnorm(x, ln2)
    return (xn, router_probs(xn, gate))


def stage_expert_fp16(xn, w1, w2, w3):
    """Full-precision expert (FP16-offload baseline + shared experts)."""
    return (expert_fp16(xn, w1, w2, w3),)


def stage_expert_quant(cfg: ModelConfig, cbits: int):
    def fn(xn, w1p, s1, z1, w2p, s2, z2, w3p, s3, z3):
        return (
            expert_quant(
                xn, w1p, s1, z1, w2p, s2, z2, w3p, s3, z3,
                cbits=cbits, group_size=cfg.group_size,
                d_ff=cfg.d_ff, d_out=cfg.d_model,
            ),
        )

    return fn


def stage_expert_quant_comp(cfg: ModelConfig, cbits: int):
    """Compensated expert — the paper's top-n restore path (§3.2)."""

    def fn(
        xn,
        w1p, s1, z1, w2p, s2, z2, w3p, s3, z3,
        u1p, u1s, u1z, v1p, v1s, v1z,
        u2p, u2s, u2z, v2p, v2s, v2z,
        u3p, u3s, u3z, v3p, v3s, v3z,
    ):
        return (
            expert_quant_comp(
                xn,
                (w1p, s1, z1), (w2p, s2, z2), (w3p, s3, z3),
                (u1p, u1s, u1z, v1p, v1s, v1z),
                (u2p, u2s, u2z, v2p, v2s, v2z),
                (u3p, u3s, u3z, v3p, v3s, v3z),
                cbits=cbits, group_size=cfg.group_size,
                d_ff=cfg.d_ff, d_out=cfg.d_model, rank=cfg.rank_pad,
            ),
        )

    return fn


def stage_head(x: jnp.ndarray, ln_f: jnp.ndarray, emb: jnp.ndarray):
    """(x (N,d), ln_f, emb) -> logits (N, V) with tied embedding head."""
    return (rmsnorm(x, ln_f) @ emb.T,)

"""AOT artifact builder — the single entry point of the python build path.

``python -m compile.aot --out-dir ../artifacts`` produces, per model config:

    artifacts/<model>/
      weights_fp32.npz     training checkpoint (trained here on first run)
      weights.beamw        runtime tensors: fp32 stage weights + packed
                           quantized experts (hqq/gptq × 2/3/4-bit) +
                           low-rank compensators (default + ablation sweep)
      eval.beamw           held-out/calibration token sets for rust evals
      router_stats.json    Fig. 3 data (router score distribution)
      kurtosis.json        Fig. 4b data (kurtosis vs quant error, ranks)
      manifest.json        stage/tensor/transfer-byte index for rust
      <stage>.hlo.txt      one AOT-lowered HLO text per inference stage

HLO *text* is the interchange format (NOT serialized protos): jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Python never runs at serve time: the rust binary consumes these files only.
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import beamw
from .compensate import (
    Compensator,
    allocate_ranks,
    allocate_uniform,
    build_compensator_from_svd,
    kurtosis,
)
from .corpus import CALIB_SEQS, CALIB_START, SyntheticCorpus, VAL_SEQS, VAL_START
from .model import (
    CONFIGS,
    ModelConfig,
    forward_train,
    stage_attn_decode,
    stage_attn_prefill,
    stage_embed,
    stage_expert_fp16,
    stage_expert_quant,
    stage_expert_quant_comp,
    stage_head,
    stage_router,
    rmsnorm,
    router_probs,
)
from .quant import quantize_gptq, quantize_hqq
from .quant.packing import container_bits, packed_nbytes, to_container
from .quant.uniform import QuantParams, dequantize, relative_residual_fro
from .train import load_or_train

PROJS = ("w1", "w2", "w3")
QUANT_BITS = (2, 3, 4)
COMP_BITS = (2, 3)
ABLATION_BUDGETS = (4, 8, 16, 32)
CALIB_TOKENS_GPTQ = 4096
V_GROUP = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def u8(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint8)


# --------------------------------------------------------------------------
# Calibration
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=0)
def _forward_collect_xn(cfg: ModelConfig, params, tokens):
    """Forward pass capturing the MoE input (xn) per layer.

    Duplicates only the attention wiring of `forward_train` (pinned against
    it by python/tests/test_model.py::test_collect_matches_train).
    """
    b, t = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    x = params["emb"][tokens]
    pos = jnp.arange(t)
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    xns = []
    for layer in params["layers"]:
        xn = rmsnorm(x, layer["ln1"])
        from .model import rope  # local import to keep module top tidy

        q = rope((xn @ layer["wq"]).reshape(b, t, h, dh), pos[None, :, None], cfg.rope_theta)
        k = rope((xn @ layer["wk"]).reshape(b, t, h, dh), pos[None, :, None], cfg.rope_theta)
        v = (xn @ layer["wv"]).reshape(b, t, h, dh)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        scores = jnp.where(causal[None, None], scores, -jnp.inf)
        attn = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, axis=-1), v)
        x = x + attn.reshape(b, t, d) @ layer["wo"]

        xn = rmsnorm(x, layer["ln2"])
        xns.append(xn.reshape(-1, d))
        probs = router_probs(xn, layer["gate"])
        from .model import topk_mask_renorm

        w = topk_mask_renorm(probs, cfg.top_k)
        gate_h = jnp.einsum("btd,edf->ebtf", xn, layer["w1"])
        up_h = jnp.einsum("btd,edf->ebtf", xn, layer["w3"])
        ey = jnp.einsum("ebtf,efd->ebtd", jax.nn.silu(gate_h) * up_h, layer["w2"])
        moe = jnp.einsum("bte,ebtd->btd", w, ey)
        if cfg.n_shared:
            sg = jnp.einsum("btd,edf->ebtf", xn, layer["sw1"])
            su = jnp.einsum("btd,edf->ebtf", xn, layer["sw3"])
            moe = moe + jnp.einsum("ebtf,efd->btd", jax.nn.silu(sg) * su, layer["sw2"])
        x = x + moe
    return jnp.stack(xns)  # (L, B*T, d)


def collect_calibration(cfg: ModelConfig, params, corpus: SyntheticCorpus, n_tokens: int):
    """Per-layer MoE-input activations + router probs over the calib split."""
    per_batch, batch_seqs = 16 * 64, 16
    xns, probs = [], [[] for _ in range(cfg.n_layers)]
    start = CALIB_START
    collected = 0
    while collected < n_tokens:
        tokens, _ = corpus.batch(start, batch_seqs)
        xn = np.asarray(_forward_collect_xn(cfg, params, jnp.asarray(tokens)))
        xns.append(xn)
        for li in range(cfg.n_layers):
            p = np.asarray(router_probs(jnp.asarray(xn[li]), params["layers"][li]["gate"]))
            probs[li].append(p)
        start += batch_seqs
        collected += per_batch
    xns = np.concatenate(xns, axis=1)[:, :n_tokens]  # (L, n, d)
    probs = [np.concatenate(p, axis=0)[:n_tokens] for p in probs]  # L × (n, E)
    return xns, probs


def router_statistics(probs_per_layer: list[np.ndarray]) -> dict:
    """Fig. 3: mean/std routing score by sorted rank position, per layer."""
    per_layer = []
    for p in probs_per_layer:
        s = np.sort(p, axis=-1)[:, ::-1]  # (n, E) descending
        per_layer.append(
            {
                "mean": s.mean(axis=0).tolist(),
                "std": s.std(axis=0).tolist(),
                "top1_share": float(s[:, 0].mean()),
            }
        )
    agg = np.stack([np.array(pl["mean"]) for pl in per_layer])
    return {
        "layers": per_layer,
        "mean_over_layers": agg.mean(axis=0).tolist(),
        "top1_range": [float(min(pl["top1_share"] for pl in per_layer)),
                       float(max(pl["top1_share"] for pl in per_layer))],
    }


# --------------------------------------------------------------------------
# Quantization products
# --------------------------------------------------------------------------

def quantize_model(cfg: ModelConfig, params, xns, quick: bool = False):
    """HQQ + GPTQ quantize every expert projection; returns nested products.

    products[(l, e, proj)] = {"fp32": W, "hqq2": QuantParams, ..., "gptq2": ...}
    kurt[(l, e, proj)] = float
    """
    g = cfg.group_size
    products: dict[tuple, dict] = {}
    kurt: dict[tuple, float] = {}
    methods = ("hqq",) if quick else ("hqq", "gptq")

    for li, layer in enumerate(params["layers"]):
        X = np.asarray(xns[li][:CALIB_TOKENS_GPTQ])  # (n, d) MoE input
        for e in range(cfg.n_experts):
            w1 = np.asarray(layer["w1"][e])
            w2 = np.asarray(layer["w2"][e])
            w3 = np.asarray(layer["w3"][e])
            # w2's calibration input is this expert's post-SiLU hidden.
            h = None
            if "gptq" in methods:
                xj = jnp.asarray(X[:2048])
                h = np.asarray(
                    jax.nn.silu(xj @ jnp.asarray(w1)) * (xj @ jnp.asarray(w3))
                )
            for proj, W, Xc in (("w1", w1, X), ("w2", w2, h), ("w3", w3, X)):
                entry = {"fp32": W}
                for bits in QUANT_BITS:
                    entry[f"hqq{bits}"] = quantize_hqq(W, bits, g)
                    if "gptq" in methods:
                        entry[f"gptq{bits}"] = quantize_gptq(W, Xc, bits, g)
                products[(li, e, proj)] = entry
                kurt[(li, e, proj)] = kurtosis(W)
    return products, kurt


def build_all_compensators(cfg: ModelConfig, products, kurt, quick: bool = False):
    """Rank allocation + residual SVDs for the default config and ablations.

    Allocation population: every expert projection matrix of the model (the
    paper's "each projection such as w1/w2/w3" reading); budget is
    ``R_avg`` per matrix.  Returns comps[tag][bits][(l,e,proj)] and a
    rank-table dict for the manifest.
    """
    keys = sorted(products.keys())
    kvec = np.array([kurt[k] for k in keys])
    max_rank = min(cfg.d_model, cfg.d_ff)

    # Precompute residual SVDs once per (matrix, bits).
    svds: dict[tuple, tuple] = {}
    for k in keys:
        for bits in COMP_BITS:
            W = products[k]["fp32"]
            E = W - dequantize(products[k][f"hqq{bits}"])
            svds[(k, bits)] = np.linalg.svd(E.astype(np.float64), full_matrices=False)

    def make(tag: str, bits: int, ranks: np.ndarray):
        out = {}
        for k, r in zip(keys, ranks):
            out[k] = build_compensator_from_svd(
                svds[(k, bits)], int(r), pad_to=cfg.rank_pad, v_group=V_GROUP
            )
        return out

    comps: dict[str, dict[int, dict]] = {}
    rank_table: dict[str, dict] = {}

    # Default: kurtosis-guided at the model's R_avg, for each comp bit-width.
    ranks_default = allocate_ranks(kvec, cfg.r_avg, cfg.rank_buckets, max_rank)
    comps["default"] = {bits: make("default", bits, ranks_default) for bits in COMP_BITS}
    rank_table["default"] = {"ranks": ranks_default.tolist(), "r_avg": cfg.r_avg}

    if not quick:
        # Ablation sweep (Fig. 8b): budgets × {kurtosis, uniform}, 2-bit.
        for budget in ABLATION_BUDGETS:
            rk = allocate_ranks(kvec, budget, cfg.rank_buckets, max_rank)
            ru = allocate_uniform(len(keys), budget)
            comps[f"r{budget}k"] = {2: make(f"r{budget}k", 2, rk)}
            comps[f"r{budget}u"] = {2: make(f"r{budget}u", 2, ru)}
            rank_table[f"r{budget}k"] = {"ranks": rk.tolist(), "r_avg": budget}
            rank_table[f"r{budget}u"] = {"ranks": ru.tolist(), "r_avg": budget}

    return comps, rank_table, keys


# --------------------------------------------------------------------------
# Tensor serialization
# --------------------------------------------------------------------------

def _quant_tensors(prefix: str, q: QuantParams) -> dict[str, np.ndarray]:
    return {
        f"{prefix}.pk": to_container(q.codes, q.bits),
        f"{prefix}.sc": q.scale,
        f"{prefix}.zp": q.zero,
    }


def _comp_tensors(prefix: str, c: Compensator) -> dict[str, np.ndarray]:
    if c.rank == 0:
        # Rank-0 still ships (exact-zero) padded factors so the comp
        # executable stays usable; transfer bytes are 0.
        raise ValueError("rank-0 compensators serialized via zero ranks table")
    return {
        f"{prefix}.up": to_container(c.u_q.codes, 3),
        f"{prefix}.us": c.u_q.scale,
        f"{prefix}.uz": c.u_q.zero,
        f"{prefix}.vp": to_container(c.v_q.codes, 3),
        f"{prefix}.vs": c.v_q.scale,
        f"{prefix}.vz": c.v_q.zero,
    }


def _zero_comp_tensors(cfg: ModelConfig, prefix: str, proj: str) -> dict[str, np.ndarray]:
    """Exact-zero padded compensator for rank-0 matrices (codes=0 @ scale 1)."""
    d, f, r = cfg.d_model, cfg.d_ff, cfg.rank_pad
    d_in, d_out = (d, f) if proj in ("w1", "w3") else (f, d)
    gu = d_in // min(64, d_in)
    gv = r // V_GROUP
    return {
        f"{prefix}.up": np.zeros((d_in, r // 2), np.uint8),
        f"{prefix}.us": np.ones((gu, r), np.float32),
        f"{prefix}.uz": np.zeros((gu, r), np.float32),
        f"{prefix}.vp": np.zeros((r, d_out // 2), np.uint8),
        f"{prefix}.vs": np.ones((gv, d_out), np.float32),
        f"{prefix}.vz": np.zeros((gv, d_out), np.float32),
    }


def serialize_weights(cfg, params, products, comps) -> dict[str, np.ndarray]:
    tensors: dict[str, np.ndarray] = {
        "emb": np.asarray(params["emb"]),
        "ln_f": np.asarray(params["ln_f"]),
    }
    for li, layer in enumerate(params["layers"]):
        for name in ("ln1", "wq", "wk", "wv", "wo", "ln2", "gate"):
            tensors[f"layers.{li}.{name}"] = np.asarray(layer[name])
        for s in range(cfg.n_shared):
            for proj in PROJS:
                tensors[f"layers.{li}.shared.{s}.{proj}"] = np.asarray(
                    layer[f"s{proj}"][s]
                )
        for e in range(cfg.n_experts):
            for proj in PROJS:
                key = (li, e, proj)
                base = f"layers.{li}.experts.{e}.{proj}"
                tensors[f"{base}.fp32"] = products[key]["fp32"]
                for variant, q in products[key].items():
                    if variant == "fp32":
                        continue
                    tensors.update(_quant_tensors(f"{base}.{variant}", q))
                for tag, by_bits in comps.items():
                    for bits, table in by_bits.items():
                        c = table[key]
                        prefix = f"{base}.comp{bits}.{tag}"
                        if c.rank == 0:
                            tensors.update(_zero_comp_tensors(cfg, prefix, proj))
                        else:
                            tensors.update(_comp_tensors(prefix, c))
    return tensors


# --------------------------------------------------------------------------
# Transfer-byte accounting (consumed by the rust link simulator)
# --------------------------------------------------------------------------

def transfer_tables(cfg: ModelConfig, products, comps, keys) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    n_params_expert = 3 * d * f
    q_bytes = {}
    for bits in QUANT_BITS:
        q = products[keys[0]][f"hqq{bits}"]
        meta_per_mat = {
            "w1": (d // cfg.group_size) * f * 4,
            "w2": (f // cfg.group_size) * d * 4,
            "w3": (d // cfg.group_size) * f * 4,
        }
        q_bytes[str(bits)] = (
            packed_nbytes(d * f, bits) * 2
            + packed_nbytes(f * d, bits)
            + sum(meta_per_mat.values())
        )
    comp_bytes = {}
    for tag, by_bits in comps.items():
        comp_bytes[tag] = {}
        for bits, table in by_bits.items():
            per_le = np.zeros((cfg.n_layers, cfg.n_experts), dtype=np.int64)
            for (li, e, proj), c in table.items():
                per_le[li, e] += c.transfer_nbytes()
            comp_bytes[tag][str(bits)] = per_le.tolist()
    return {
        "fp16_expert_bytes": n_params_expert * 2,
        "q_expert_bytes": q_bytes,
        "comp_bytes": comp_bytes,
    }


# --------------------------------------------------------------------------
# HLO stage export
# --------------------------------------------------------------------------

def stage_specs(cfg: ModelConfig) -> dict[str, tuple]:
    """(callable, example-arg specs) per stage; N differs decode vs prefill."""
    d, fdim, v, e = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_experts
    h, dh, s, g, r = cfg.n_heads, cfg.d_head, cfg.s_max, cfg.group_size, cfg.rank_pad
    B, T = cfg.b_max, cfg.t_prefill

    def expert_quant_args(n, bits):
        cb = container_bits(bits)
        return (
            f32(n, d),
            u8(d, fdim * cb // 8), f32(d // g, fdim), f32(d // g, fdim),
            u8(fdim, d * cb // 8), f32(fdim // g, d), f32(fdim // g, d),
            u8(d, fdim * cb // 8), f32(d // g, fdim), f32(d // g, fdim),
        )

    def comp_args(d_in, d_out):
        gu = d_in // min(64, d_in)
        gv = r // V_GROUP
        return (
            u8(d_in, r // 2), f32(gu, r), f32(gu, r),
            u8(r, d_out // 2), f32(gv, d_out), f32(gv, d_out),
        )

    stages = {
        "embed_d": (stage_embed, (i32(B), f32(v, d))),
        "embed_p": (stage_embed, (i32(T), f32(v, d))),
        "attn_d": (
            stage_attn_decode(cfg),
            (f32(B, d), f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d),
             f32(B, h, s, dh), f32(B, h, s, dh), i32(B)),
        ),
        "attn_p": (
            stage_attn_prefill(cfg),
            (f32(T, d), f32(d), f32(d, d), f32(d, d), f32(d, d), f32(d, d)),
        ),
        "router_d": (stage_router, (f32(B, d), f32(d), f32(d, e))),
        "router_p": (stage_router, (f32(T, d), f32(d), f32(d, e))),
        "head_d": (stage_head, (f32(B, d), f32(d), f32(v, d))),
        # head over prefill rows: teacher-forced scoring (accuracy harness)
        "head_p": (stage_head, (f32(T, d), f32(d), f32(v, d))),
    }
    for n, suffix in ((B, "d"), (T, "p")):
        stages[f"expert_fp16_{suffix}"] = (
            stage_expert_fp16,
            (f32(n, d), f32(d, fdim), f32(fdim, d), f32(d, fdim)),
        )
        for bits in QUANT_BITS:
            stages[f"expert_q{bits}_{suffix}"] = (
                stage_expert_quant(cfg, container_bits(bits)),
                expert_quant_args(n, bits),
            )
        for bits in COMP_BITS:
            stages[f"expert_q{bits}c_{suffix}"] = (
                stage_expert_quant_comp(cfg, container_bits(bits)),
                expert_quant_args(n, bits)
                + comp_args(d, fdim) + comp_args(fdim, d) + comp_args(d, fdim),
            )
    return stages


def export_stages(cfg: ModelConfig, out: pathlib.Path) -> dict:
    index = {}
    for name, (fn, specs) in stage_specs(cfg).items():
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        index[name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        }
        print(f"  lowered {name:18s} {len(text)//1024:5d} KiB  {time.time()-t0:.1f}s")
    return index


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------

def build_model(cfg: ModelConfig, out_root: pathlib.Path, quick: bool = False):
    out = out_root / cfg.name
    out.mkdir(parents=True, exist_ok=True)
    corpus = SyntheticCorpus()

    print(f"[{cfg.name}] loading / training weights …")
    params = load_or_train(cfg, out_root, steps=120 if quick else 600)

    print(f"[{cfg.name}] calibration forward …")
    n_calib = 2048 if quick else CALIB_SEQS * 64
    xns, probs = collect_calibration(cfg, params, corpus, n_calib)
    stats = router_statistics(probs)
    (out / "router_stats.json").write_text(json.dumps(stats, indent=1))
    print(f"  top-1 router share: {stats['top1_range']}")

    print(f"[{cfg.name}] quantizing experts (hqq{'' if quick else '+gptq'} × {QUANT_BITS}) …")
    products, kurt = quantize_model(cfg, params, xns, quick)

    print(f"[{cfg.name}] building compensators …")
    comps, rank_table, keys = build_all_compensators(cfg, products, kurt, quick)

    # Fig. 4b data: kurtosis vs relative quant error per matrix.
    fig4 = [
        {
            "key": f"{li}.{e}.{proj}",
            "kurtosis": kurt[(li, e, proj)],
            "err": {
                str(b): relative_residual_fro(
                    products[(li, e, proj)]["fp32"], products[(li, e, proj)][f"hqq{b}"]
                )
                for b in QUANT_BITS
            },
        }
        for (li, e, proj) in keys
    ]
    (out / "kurtosis.json").write_text(json.dumps(fig4, indent=1))

    print(f"[{cfg.name}] serializing weights …")
    tensors = serialize_weights(cfg, params, products, comps)
    beamw.write(out / "weights.beamw", tensors)

    val_tokens, val_det = corpus.batch(VAL_START, VAL_SEQS)
    calib_tokens, _ = corpus.batch(CALIB_START, 64)
    beamw.write(
        out / "eval.beamw",
        {
            "val_tokens": val_tokens.astype(np.int32),
            "val_det": val_det.astype(np.int8),
            "calib_tokens": calib_tokens.astype(np.int32),
        },
    )

    print(f"[{cfg.name}] lowering stages …")
    stage_index = export_stages(cfg, out)

    manifest = {
        "model": {
            "name": cfg.name, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "d_ff": cfg.d_ff, "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_experts": cfg.n_experts, "top_k": cfg.top_k,
            "n_shared": cfg.n_shared, "s_max": cfg.s_max,
            "t_prefill": cfg.t_prefill, "b_max": cfg.b_max,
            "group_size": cfg.group_size, "rank_pad": cfg.rank_pad,
            "r_avg": cfg.r_avg, "top_n": cfg.top_n,
        },
        "stages": stage_index,
        "quant": {
            "methods": ["hqq"] if quick else ["hqq", "gptq"],
            "bits": list(QUANT_BITS),
            "comp_bits": list(COMP_BITS),
            "container_bits": {str(b): container_bits(b) for b in QUANT_BITS},
            "v_group": V_GROUP,
        },
        "comp_tags": {tag: sorted(by.keys()) for tag, by in comps.items()},
        "rank_table": rank_table,
        "mat_keys": [f"{li}.{e}.{proj}" for (li, e, proj) in keys],
        "transfer": transfer_tables(cfg, products, comps, keys),
        "files": {
            "weights": "weights.beamw",
            "eval": "eval.beamw",
            "router_stats": "router_stats.json",
            "kurtosis": "kurtosis.json",
        },
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[{cfg.name}] done → {out}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(CONFIGS))
    ap.add_argument("--quick", action="store_true",
                    help="short training, hqq-only, no ablation sweep (CI)")
    args = ap.parse_args()
    out_root = pathlib.Path(args.out_dir)
    for name in args.models:
        build_model(CONFIGS[name], out_root, quick=args.quick)
    (out_root / "MANIFEST").write_text(
        json.dumps({"models": args.models, "quick": args.quick})
    )


if __name__ == "__main__":
    main()

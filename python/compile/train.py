"""Training loop for the tiny MoE LMs (build-time only).

Trains each `ModelConfig` for a few hundred AdamW steps on the synthetic
corpus so that (a) the router develops the skewed score distribution the
paper's mechanism exploits and (b) perplexity / cloze accuracy respond
meaningfully to quantization error.  Checkpoints land in
``artifacts/<model>/weights_fp32.npz`` and are consumed by ``aot.py``.

Run directly (``python -m compile.train mixtral-tiny``) or implicitly via
``make artifacts`` (aot.py trains on demand when no checkpoint exists).
"""

from __future__ import annotations

import functools
import pathlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from .corpus import SyntheticCorpus, TRAIN_START, TRAIN_SEQS, VAL_START, VAL_SEQS
from .model import CONFIGS, ModelConfig, forward_train, init_params

BATCH = 16
STEPS = 600
LR_PEAK = 3e-3
WARMUP = 50
AUX_COEF = 0.01
SEED = 0


def loss_fn(cfg: ModelConfig, params, tokens):
    logits, aux = forward_train(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return nll + AUX_COEF * aux, nll


def lr_at(step):
    warm = jnp.minimum(step / WARMUP, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(step / STEPS, 1.0)))
    return LR_PEAK * warm * (0.1 + 0.9 * cos)


@functools.partial(jax.jit, static_argnums=0)
def train_step(cfg: ModelConfig, params, opt, tokens, step):
    (loss, nll), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens), has_aux=True
    )(params)
    lr = lr_at(step)
    b1, b2, eps, wd = 0.9, 0.95, 1e-8, 0.01

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (step + 1))
        vh = v / (1 - b2 ** (step + 1))
        return p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p), m, v

    new = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    params = jax.tree.map(lambda t: t[0], new, is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], new, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], new, is_leaf=lambda t: isinstance(t, tuple))
    return params, {"m": m, "v": v}, nll


@functools.partial(jax.jit, static_argnums=0)
def eval_nll(cfg: ModelConfig, params, tokens):
    _, nll = loss_fn(cfg, params, tokens)
    return nll


def flatten_params(cfg: ModelConfig, params) -> dict[str, np.ndarray]:
    """Flatten the pytree into the name->array map stored in the npz."""
    out = {"emb": params["emb"], "ln_f": params["ln_f"]}
    for li, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            out[f"layers.{li}.{k}"] = v
    return {k: np.asarray(v) for k, v in out.items()}


def unflatten_params(cfg: ModelConfig, flat: dict) -> dict:
    params = {"emb": jnp.asarray(flat["emb"]), "ln_f": jnp.asarray(flat["ln_f"]), "layers": []}
    for li in range(cfg.n_layers):
        prefix = f"layers.{li}."
        layer = {
            k[len(prefix):]: jnp.asarray(v)
            for k, v in flat.items()
            if k.startswith(prefix)
        }
        params["layers"].append(layer)
    return params


def train(cfg: ModelConfig, out_path: pathlib.Path, steps: int = STEPS) -> dict:
    corpus = SyntheticCorpus()
    params = init_params(cfg, jax.random.PRNGKey(SEED))
    opt = {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
    }
    val_tokens, _ = corpus.batch(VAL_START, VAL_SEQS)
    val_tokens = jnp.asarray(val_tokens[:64])

    t0 = time.time()
    for step in range(steps):
        start = TRAIN_START + (step * BATCH) % TRAIN_SEQS
        tokens, _ = corpus.batch(start, BATCH)
        params, opt, nll = train_step(cfg, params, opt, jnp.asarray(tokens), step)
        if step % 100 == 0 or step == steps - 1:
            vn = float(eval_nll(cfg, params, val_tokens))
            print(
                f"[{cfg.name}] step {step:4d} train_nll={float(nll):.4f} "
                f"val_nll={vn:.4f} val_ppl={np.exp(vn):.2f} ({time.time()-t0:.0f}s)",
                flush=True,
            )

    out_path.parent.mkdir(parents=True, exist_ok=True)
    np.savez(out_path, **flatten_params(cfg, params))
    print(f"[{cfg.name}] wrote {out_path}")
    return params


def load_or_train(cfg: ModelConfig, artifacts_dir: pathlib.Path, steps: int = STEPS) -> dict:
    path = artifacts_dir / cfg.name / "weights_fp32.npz"
    if path.exists():
        flat = dict(np.load(path))
        return unflatten_params(cfg, flat)
    return train(cfg, path, steps)


if __name__ == "__main__":
    names = sys.argv[1:] or list(CONFIGS)
    for name in names:
        train(CONFIGS[name], pathlib.Path("../artifacts") / name / "weights_fp32.npz")

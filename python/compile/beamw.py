"""BEAMW — the trivial binary tensor container shared by python and rust.

Layout (little-endian):

    magic   b"BEAMW001"                       (8 bytes)
    hlen    u64: byte length of the header    (8 bytes)
    header  JSON: {"tensors": [{"name", "dtype", "shape", "offset", "nbytes"}]}
    blob    concatenated raw tensor bytes; offsets are blob-relative

dtypes: "f32", "i32", "u8", "i8".  The rust reader is
``rust/src/manifest.rs::WeightStore`` — a format change here must bump the
magic and be mirrored there (pinned by an integration test over a golden
file).  Chosen over npz to keep the rust side free of zip/ndarray deps.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np

MAGIC = b"BEAMW001"

_DTYPES = {
    np.dtype(np.float32): "f32",
    np.dtype(np.int32): "i32",
    np.dtype(np.uint8): "u8",
    np.dtype(np.int8): "i8",
}
_NP_DTYPES = {v: k for k, v in _DTYPES.items()}


def write(path: str | pathlib.Path, tensors: dict[str, np.ndarray]) -> None:
    entries = []
    offset = 0
    blobs = []
    for name, arr in tensors.items():
        arr = np.ascontiguousarray(arr)
        if arr.dtype not in _DTYPES:
            raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
        raw = arr.tobytes()
        entries.append(
            {
                "name": name,
                "dtype": _DTYPES[arr.dtype],
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            }
        )
        blobs.append(raw)
        offset += len(raw)

    header = json.dumps({"tensors": entries}).encode()
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        for b in blobs:
            f.write(b)


def read(path: str | pathlib.Path) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r}")
        hlen = int.from_bytes(f.read(8), "little")
        header = json.loads(f.read(hlen))
        blob = f.read()
    out = {}
    for e in header["tensors"]:
        raw = blob[e["offset"] : e["offset"] + e["nbytes"]]
        out[e["name"]] = np.frombuffer(raw, dtype=_NP_DTYPES[e["dtype"]]).reshape(
            e["shape"]
        ).copy()
    return out

"""Offline low-rank compensation pipeline (paper §3.1).

Step 1 — kurtosis-guided rank allocation: compute the (Pearson) kurtosis of
every expert weight matrix, sort descending, and greedily hand out the
largest feasible rank bucket under the global average budget ``R_avg``.

Step 2 — one-time SVD: quantize with HQQ, take the residual
``E = W − Q⁻¹(Q(W))``, truncated-SVD it at the allocated rank, fold in
``√S`` on both sides, and 3-bit-quantize the factors (the compensator that
crosses the link is itself low-bit).

The output of this module (a :class:`Compensator` per weight matrix) is what
``aot.py`` serializes into ``artifacts/`` for the rust coordinator.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .quant.uniform import QuantParams, quantize_uniform, dequantize
from .quant.packing import packed_nbytes

#: Paper bucket set (§3.1).  Tiny-model builds pass a scaled-down set — the
#: greedy policy is bucket-set agnostic.
PAPER_BUCKETS = (0, 16, 32, 128, 256, 512, 1024)


def kurtosis(W: np.ndarray) -> float:
    """Pearson kurtosis over all elements (paper eq. in §3.1; ≈3 for Gaussian)."""
    W = np.asarray(W, dtype=np.float64).ravel()
    mu = W.mean()
    sigma2 = W.var()
    if sigma2 <= 1e-24:
        return 0.0
    return float(np.mean((W - mu) ** 4) / sigma2**2)


def allocate_ranks(
    kurtoses: np.ndarray,
    r_avg: int,
    buckets: tuple[int, ...] = PAPER_BUCKETS,
    max_rank: int | None = None,
) -> np.ndarray:
    """Greedy kurtosis-guided bucket assignment (paper §3.1 step 1).

    Sort experts by descending kurtosis; walking the sorted list, give each
    expert the largest bucket that keeps ``sum(r) <= N * r_avg``.  Experts
    with equal kurtosis are ordered by index for determinism.

    ``max_rank`` clamps buckets to ``min(m, n)`` of the matrices involved
    (relevant for the tiny reproduction models).
    """
    kurtoses = np.asarray(kurtoses, dtype=np.float64)
    n = kurtoses.shape[0]
    budget = int(n * r_avg)
    feasible = sorted({b for b in buckets if max_rank is None or b <= max_rank})
    if not feasible or feasible[0] != 0:
        feasible = [0] + feasible

    order = np.lexsort((np.arange(n), -kurtoses))  # desc kurtosis, asc index
    ranks = np.zeros(n, dtype=np.int64)
    spent = 0
    for idx in order:
        # Largest bucket that still fits the remaining global budget.
        for b in reversed(feasible):
            if spent + b <= budget:
                ranks[idx] = b
                spent += b
                break
    return ranks


def allocate_uniform(n_experts: int, r_avg: int) -> np.ndarray:
    """Uniform assignment baseline (paper Fig. 8b ablation)."""
    return np.full(n_experts, r_avg, dtype=np.int64)


@dataclasses.dataclass
class Compensator:
    """Low-rank residual compensator for one weight matrix.

    ``U`` is (d_in, r), ``V`` is (r, d_out) after the √S reparameterization;
    both are stored 3-bit quantized (``u_q``/``v_q``) — that is what crosses
    the PCIe/NDP link at inference time.  ``rank == 0`` is a valid empty
    compensator (zero bytes, identity restore).

    When ``pad_to`` was given at build time the stored factors are zero-padded
    to a fixed ``pad_to`` columns/rows so that *one* AOT executable (whose
    shapes are static) serves every rank bucket; padding columns quantize
    exactly to zero (they get their own per-column scale/zero) and contribute
    nothing to ``U@V``.  Bandwidth accounting always uses the *true* rank.
    """

    rank: int
    u_q: QuantParams | None
    v_q: QuantParams | None
    d_in: int = 0
    d_out: int = 0

    def factors(self) -> tuple[np.ndarray, np.ndarray]:
        """Dequantized (U, V) as the runtime reconstructs them."""
        if self.rank == 0:
            raise ValueError("rank-0 compensator has no factors")
        return dequantize(self.u_q), dequantize(self.v_q)

    def delta(self) -> np.ndarray:
        """The weight-space correction ``U @ V`` this compensator applies."""
        if self.rank == 0:
            raise ValueError("rank-0 compensator has no factors")
        u, v = self.factors()
        return u @ v

    def transfer_nbytes(self) -> int:
        """Bytes on the wire: 3-bit packed factors + fp16 scale/zero meta.

        Charged on the *true* rank — padding introduced for executable-shape
        reuse never crosses the link (the runtime ships true-rank factors and
        zero-extends on device, a free operation).
        """
        if self.rank == 0:
            return 0
        n_u = self.d_in * self.rank
        n_v = self.rank * self.d_out
        total = packed_nbytes(_pad8(n_u), 3) + packed_nbytes(_pad8(n_v), 3)
        # fp16 scale+zero for the true-rank factor groups.
        g_u = self.u_q.scale.shape[0]
        g_v = max(1, self.rank // max(1, self.v_q.group_size))
        total += (g_u * self.rank) * 2 * 2 + (g_v * self.d_out) * 2 * 2
        return total


def _pad8(n: int) -> int:
    """Round code count up to the 8-code chunk of the 3-bit codec."""
    return (n + 7) // 8 * 8


def _factor_group_size(rows: int, preferred: int = 64) -> int:
    """Largest group size ≤ preferred that divides ``rows`` (ranks can be tiny)."""
    g = min(preferred, rows)
    while rows % g != 0:
        g -= 1
    return g


def build_compensator(
    W: np.ndarray,
    q: QuantParams,
    rank: int,
    factor_bits: int = 3,
    factor_group: int = 64,
    pad_to: int | None = None,
    v_group: int = 4,
) -> Compensator:
    """Truncated-SVD residual compensator (paper §3.1 step 2).

    ``E = W − Q⁻¹(Q(W))``;  ``U, S, Vᵀ = SVD_r(E)``;  ``U ← U√S, V ← √S Vᵀ``;
    then 3-bit quantize both factors.

    ``pad_to`` zero-pads the float factors to a fixed rank before
    quantization so all compensators of a model share one executable shape
    (padded columns/rows quantize exactly to zero — see class docstring).
    ``v_group`` must divide every rank bucket so padded V rows fall in
    all-zero groups and stay exact.
    """
    E = np.asarray(W, dtype=np.float32) - dequantize(q)
    svd = np.linalg.svd(E.astype(np.float64), full_matrices=False)
    return build_compensator_from_svd(
        svd, rank,
        factor_bits=factor_bits, factor_group=factor_group,
        pad_to=pad_to, v_group=v_group,
    )


def build_compensator_from_svd(
    svd: tuple[np.ndarray, np.ndarray, np.ndarray],
    rank: int,
    factor_bits: int = 3,
    factor_group: int = 64,
    pad_to: int | None = None,
    v_group: int = 4,
) -> Compensator:
    """Same as :func:`build_compensator` from a precomputed residual SVD.

    ``aot.py`` sweeps many rank budgets over the same residual; the SVD is
    computed once per (matrix, bit-width) and sliced here per budget.
    """
    U, S, Vt = svd
    d_in, d_out = U.shape[0], Vt.shape[1]
    rank = int(min(rank, d_in, d_out))
    if rank == 0:
        return Compensator(rank=0, u_q=None, v_q=None, d_in=d_in, d_out=d_out)

    U, S, Vt = U[:, :rank], S[:rank], Vt[:rank, :]
    sqrt_s = np.sqrt(S)
    Uf = (U * sqrt_s[None, :]).astype(np.float32)  # (d_in, r)
    Vf = (sqrt_s[:, None] * Vt).astype(np.float32)  # (r, d_out)

    stored_rank = rank
    if pad_to is not None:
        if pad_to < rank:
            raise ValueError(f"pad_to={pad_to} < rank={rank}")
        if rank % v_group != 0:
            raise ValueError(f"rank {rank} not a multiple of v_group {v_group}")
        stored_rank = pad_to
        Uf = np.pad(Uf, ((0, 0), (0, pad_to - rank)))
        Vf = np.pad(Vf, ((0, pad_to - rank), (0, 0)))

    u_q = quantize_uniform(Uf, factor_bits, _factor_group_size(Uf.shape[0], factor_group))
    v_q = quantize_uniform(Vf, factor_bits, min(v_group, stored_rank))
    return Compensator(rank=rank, u_q=u_q, v_q=v_q, d_in=d_in, d_out=d_out)


def compensated_weight(q: QuantParams, comp: Compensator) -> np.ndarray:
    """Runtime restore: ``Ŵ = Q⁻¹(Q(W)) + U V`` (paper §3.2)."""
    W = dequantize(q)
    if comp.rank > 0:
        W = W + comp.delta()
    return W


def residual_curve(W: np.ndarray, q: QuantParams, ranks: list[int]) -> list[float]:
    """‖E − UV‖_F/‖W‖_F at each rank — regenerates paper Fig. 4a."""
    W = np.asarray(W, dtype=np.float32)
    E = W - dequantize(q)
    U, S, Vt = np.linalg.svd(E.astype(np.float64), full_matrices=False)
    wnorm = float(np.linalg.norm(W)) or 1.0
    out = []
    for r in ranks:
        r = int(min(r, S.shape[0]))
        # ‖E − E_r‖_F² = Σ_{i>r} σ_i²  (Eckart–Young)
        tail = float(np.sqrt((S[r:] ** 2).sum()))
        out.append(tail / wnorm)
    return out

"""Deterministic synthetic corpus with learnable structure.

Stands in for the paper's natural-language data (C4 calibration, WikiText
perplexity, commonsense-reasoning suites — DESIGN.md §3 substitutions).  The
generator produces token sequences from a small probabilistic grammar over a
{vocab_size}-token vocabulary:

* **templated clauses** — SUBJ VERB OBJ [ADV] with *agreement rules*
  (each subject class selects a verb class; each verb class selects an
  object class), so a model must learn long-range conditional structure;
* **copy/arithmetic motifs** — ``<rep> a b a b``, ``<cnt> k k+1 k+2``
  patterns with exactly-predictable continuations;
* **zipfian filler** unigrams, making token frequencies realistic.

Because several token positions are *fully determined* by their prefix, the
corpus supports a cloze accuracy metric (predict the determined token) that
degrades smoothly with model quality — our stand-in for the paper's
zero-shot reasoning accuracy.  Perplexity on held-out sequences stands in
for WikiText PPL.

Everything is seeded; python (training/eval) and rust (serving workloads,
accuracy harness) regenerate identical streams from the token dumps written
by ``aot.py``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Special tokens
PAD, BOS, EOS, SEP, REP, CNT = 0, 1, 2, 3, 4, 5
N_SPECIAL = 6

# Vocabulary regions (within vocab_size=512)
N_SUBJ, N_VERB, N_OBJ, N_ADV = 48, 48, 48, 32
N_CLASSES = 8  # agreement classes


@dataclasses.dataclass
class CorpusConfig:
    vocab_size: int = 512
    seq_len: int = 64
    seed: int = 1234
    p_clause: float = 0.55
    p_motif: float = 0.25  # rep/cnt motifs
    # remainder: zipfian filler


class SyntheticCorpus:
    """Seeded generator; every batch is a pure function of (seed, counter)."""

    def __init__(self, cfg: CorpusConfig | None = None):
        self.cfg = cfg or CorpusConfig()
        v = self.cfg.vocab_size
        base = N_SPECIAL
        self.subj = np.arange(base, base + N_SUBJ)
        self.verb = np.arange(base + N_SUBJ, base + N_SUBJ + N_VERB)
        self.obj = np.arange(base + N_SUBJ + N_VERB, base + N_SUBJ + N_VERB + N_OBJ)
        self.adv = np.arange(
            base + N_SUBJ + N_VERB + N_OBJ, base + N_SUBJ + N_VERB + N_OBJ + N_ADV
        )
        self.filler = np.arange(base + N_SUBJ + N_VERB + N_OBJ + N_ADV, v)
        # Zipf weights for filler tokens.
        ranks = np.arange(1, len(self.filler) + 1, dtype=np.float64)
        self.filler_p = (1.0 / ranks) / (1.0 / ranks).sum()
        # Deterministic agreement maps: subj class -> verb class -> obj class.
        rng = np.random.default_rng(self.cfg.seed * 7 + 3)
        self.subj_to_verb_class = rng.permutation(N_CLASSES)
        self.verb_to_obj_class = rng.permutation(N_CLASSES)

    # -- helpers ---------------------------------------------------------
    def _class_of(self, tok_region: np.ndarray, tok: int) -> int:
        return int(np.where(tok_region == tok)[0][0]) % N_CLASSES

    def _pick(self, rng, region: np.ndarray, cls: int) -> int:
        members = region[cls::N_CLASSES]
        return int(rng.choice(members))

    def _clause(self, rng) -> tuple[list[int], list[int]]:
        """Returns (tokens, determined_mask) for one agreement clause.

        The object token's *class* is fully determined by the verb; we mark
        the object position as cloze-predictable (class-level: the eval
        checks the predicted token falls in the correct class region+class).
        """
        s = int(rng.choice(self.subj))
        s_cls = self._class_of(self.subj, s)
        v_cls = int(self.subj_to_verb_class[s_cls])
        v = self._pick(rng, self.verb, v_cls)
        o_cls = int(self.verb_to_obj_class[v_cls])
        o = self._pick(rng, self.obj, o_cls)
        toks, det = [s, v, o], [0, 1, 1]
        if rng.random() < 0.4:
            toks.append(int(rng.choice(self.adv)))
            det.append(0)
        toks.append(SEP)
        det.append(0)
        return toks, det

    def _motif(self, rng) -> tuple[list[int], list[int]]:
        if rng.random() < 0.5:
            a, b = rng.choice(self.filler, size=2, replace=False)
            toks = [REP, int(a), int(b), int(a), int(b), int(a), SEP]
            det = [0, 0, 0, 1, 1, 1, 0]
        else:
            k = int(rng.integers(0, len(self.filler) - 4))
            f = self.filler
            toks = [CNT, int(f[k]), int(f[k + 1]), int(f[k + 2]), int(f[k + 3]), SEP]
            det = [0, 0, 1, 1, 1, 0]
        return toks, det

    def _filler_run(self, rng) -> tuple[list[int], list[int]]:
        n = int(rng.integers(2, 6))
        toks = [int(t) for t in rng.choice(self.filler, size=n, p=self.filler_p)]
        toks.append(SEP)
        return toks, [0] * (n + 1)

    # -- public API ------------------------------------------------------
    def sequence(self, index: int) -> tuple[np.ndarray, np.ndarray]:
        """Deterministic sequence #index: (tokens[seq_len], determined[seq_len])."""
        rng = np.random.default_rng((self.cfg.seed, index))
        toks, det = [BOS], [0]
        while len(toks) < self.cfg.seq_len:
            r = rng.random()
            if r < self.cfg.p_clause:
                t, d = self._clause(rng)
            elif r < self.cfg.p_clause + self.cfg.p_motif:
                t, d = self._motif(rng)
            else:
                t, d = self._filler_run(rng)
            toks.extend(t)
            det.extend(d)
        toks = np.array(toks[: self.cfg.seq_len], dtype=np.int32)
        det = np.array(det[: self.cfg.seq_len], dtype=np.int8)
        return toks, det

    def batch(self, start: int, n: int) -> tuple[np.ndarray, np.ndarray]:
        seqs, dets = zip(*(self.sequence(start + i) for i in range(n)))
        return np.stack(seqs), np.stack(dets)

    def object_class_members(self, tok: int) -> np.ndarray:
        """All object tokens in the same agreement class as ``tok`` (for cloze)."""
        cls = self._class_of(self.obj, tok)
        return self.obj[cls::N_CLASSES]


# Canonical dataset splits used across python/rust (index ranges).
TRAIN_START, TRAIN_SEQS = 0, 4096
VAL_START, VAL_SEQS = 100_000, 256
CALIB_START, CALIB_SEQS = 200_000, 1280  # 1280*64 ≈ 80K calibration tokens (Fig. 3)

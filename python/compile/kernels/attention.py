"""Decode-step attention kernel over a KV cache.

One query token per sequence slot attends to its cache prefix.  Grid is
``(batch, heads)``; each step stages one head's ``(S, dh)`` K and V panels
into VMEM, computes masked scores against the single query row, applies a
numerically-stable softmax, and contracts with V.  ``lengths`` (how much of
each slot's cache is valid) arrives as a scalar-prefetch-style small operand;
masking uses an iota comparison so the kernel is shape-static.

The tiny-model caches (S ≤ 320, dh ≤ 64) fit a single VMEM block per head;
for longer S this kernel would tile over the S axis with an online softmax
(flash-style) — noted in DESIGN.md §Perf as the TPU scaling path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, s_max, scale):
    q = q_ref[0, 0, :]  # (dh,)
    k = k_ref[0, 0, :, :]  # (S, dh)
    v = v_ref[0, 0, :, :]  # (S, dh)
    n = len_ref[0, 0]  # valid prefix length for this slot

    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # (S,)
    pos = jax.lax.broadcasted_iota(jnp.int32, (s_max,), 0)
    scores = jnp.where(pos < n, scores, -jnp.inf)
    m = jnp.max(scores)
    e = jnp.exp(scores - m)
    o_ref[0, 0, :] = jnp.dot(e, v, preferred_element_type=jnp.float32) / jnp.sum(e)


def decode_attention(
    q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray, lengths: jnp.ndarray
) -> jnp.ndarray:
    """Single-token attention: ``q`` (B, H, dh), caches (B, H, S, dh), ``lengths`` (B,).

    Returns (B, H, dh).  Cache positions ≥ ``lengths[b]`` are masked out, so
    slots may carry stale garbage beyond their valid prefix (the rust KV
    manager relies on this: freed slots are reused without zeroing).
    """
    b, h, dh = q.shape
    s_max = k_cache.shape[2]
    lens = jnp.broadcast_to(lengths[:, None], (b, h)).astype(jnp.int32)

    kernel = functools.partial(_attn_kernel, s_max=s_max, scale=1.0 / (dh**0.5))
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, s_max, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s_max, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), jnp.float32),
        interpret=True,
    )(q, k_cache, v_cache, lens)

"""L1 — Pallas kernels for BEAM's compute hot path.

All kernels are built with ``interpret=True`` (DESIGN.md §Hardware-
Adaptation): real-TPU lowering emits Mosaic custom-calls the CPU PJRT plugin
cannot execute, so correctness is validated through the interpret path while
TPU efficiency is *estimated* from BlockSpec VMEM footprints (see
EXPERIMENTS.md §Perf).

Kernels
-------
quant_matmul      packed low-bit dequant-matmul, group-wise (scale, zero)
lowrank_delta     (x·U)·V low-rank activation-space correction, INT-packed factors
expert            fused SwiGLU MoE expert (fp16 and quantized variants)
attention         decode-step attention over a KV cache

``ref.py`` holds the pure-jnp oracles each kernel is pinned against in
``python/tests/``.
"""

from .quant_matmul import quant_matmul
from .lowrank import lowrank_delta
from .expert import expert_fp16, expert_quant, expert_quant_comp
from .attention import decode_attention

__all__ = [
    "quant_matmul",
    "lowrank_delta",
    "expert_fp16",
    "expert_quant",
    "expert_quant_comp",
    "decode_attention",
]

"""Low-rank compensation delta kernel: ``Δy = (x · U) · V``.

This is the *runtime* half of the paper's contribution (§3.2).  Because the
compensated weight ``Ŵ = Q⁻¹(Q(W)) + U V`` enters the layer linearly, the
restoration can be applied in activation space:

    y_restored = x · Ŵ = x · Q⁻¹(Q(W))  +  (x · U) · V
                 └── quant_matmul ──┘     └── this kernel ──┘

which avoids materializing Ŵ (an ``m×n`` write + re-read per token batch)
and costs only ``O(r(m+n))`` — the same reason the compensator is cheap on
the wire makes it cheap on the MXU.  The ablation bench
``hotpath_delta_vs_reconstruct`` quantifies this against explicit weight
reconstruction.

Factors arrive 3-bit quantized in 4-bit containers with their own group-wise
(scale, zero); both stages dequant in-VMEM.  Ranks are ≤128 for the tiny
models (≤1024 in the paper), so ``x·U`` stays resident between the two
matmuls — a single-block kernel with no grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant_matmul import unpack_container, dequant_block


def _delta_kernel(
    x_ref, up_ref, us_ref, uz_ref, vp_ref, vs_ref, vz_ref, o_ref,
    *, cbits, rank, d_out, u_group, v_group,
):
    x = x_ref[...]  # (B, d_in)
    u = dequant_block(
        unpack_container(up_ref[...], cbits, rank), us_ref[...], uz_ref[...], u_group
    )  # (d_in, r)
    v = dequant_block(
        unpack_container(vp_ref[...], cbits, d_out), vs_ref[...], vz_ref[...], v_group
    )  # (r, d_out)
    xu = jnp.dot(x, u, preferred_element_type=jnp.float32)  # (B, r) — VMEM-resident
    o_ref[...] = jnp.dot(xu, v, preferred_element_type=jnp.float32)


def lowrank_delta(
    x: jnp.ndarray,
    u_packed: jnp.ndarray,
    u_scale: jnp.ndarray,
    u_zero: jnp.ndarray,
    v_packed: jnp.ndarray,
    v_scale: jnp.ndarray,
    v_zero: jnp.ndarray,
    *,
    rank: int,
    d_out: int,
    cbits: int = 4,
    u_group: int | None = None,
    v_group: int | None = None,
) -> jnp.ndarray:
    """Compute the activation-space correction ``(x @ U) @ V``.

    Shapes: ``x`` (B, d_in); ``u_packed`` (d_in, rank·cbits/8);
    ``v_packed`` (rank, d_out·cbits/8); metadata per quant group as in
    `quant_matmul`.  Group sizes are inferred from the metadata shapes when
    not given (ranks can be smaller than the default group of 64).
    """
    b, d_in = x.shape
    if u_group is None:
        u_group = d_in // u_scale.shape[0]
    if v_group is None:
        v_group = rank // v_scale.shape[0]

    kernel = functools.partial(
        _delta_kernel,
        cbits=cbits, rank=rank, d_out=d_out, u_group=u_group, v_group=v_group,
    )
    full = lambda shape: pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))
    return pl.pallas_call(
        kernel,
        in_specs=[
            full(x.shape),
            full(u_packed.shape), full(u_scale.shape), full(u_zero.shape),
            full(v_packed.shape), full(v_scale.shape), full(v_zero.shape),
        ],
        out_specs=full((b, d_out)),
        out_shape=jax.ShapeDtypeStruct((b, d_out), jnp.float32),
        interpret=True,
    )(x, u_packed, u_scale, u_zero, v_packed, v_scale, v_zero)

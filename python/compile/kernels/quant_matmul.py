"""Packed low-bit dequant-matmul pallas kernel.

Computes ``y = x @ Q⁻¹(W)`` where ``W`` arrives as *packed* integer codes in
the kernel-container format (``quant.packing.to_container``): ``cbits``-bit
fields packed little-endian inside each byte along the **output** axis, plus
group-wise float ``(scale, zero)`` over the contraction axis.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks output tiles;
per step the BlockSpec stages one ``(d_in, tile/cpb)`` packed byte-block plus
its ``(G, tile)`` metadata HBM→VMEM, unpacks and dequantizes in-register, and
feeds the MXU with an ``(B, d_in) × (d_in, tile)`` matmul.  The packed block
is ``8/cbits×`` smaller than the f32 weights — exactly the bandwidth saving
the paper buys on the PCIe link, realized here on the HBM↔VMEM path.

Run under ``interpret=True`` everywhere (CPU PJRT cannot execute Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def unpack_container(packed: jnp.ndarray, cbits: int, d_out: int) -> jnp.ndarray:
    """Unpack ``cbits``-bit fields from bytes along the last axis (jnp, in-kernel).

    Mirrors ``quant.packing.unpack_codes`` for container bit-widths
    ``{2, 4, 8}`` (3-bit codes ride in a 4-bit container).
    """
    if cbits == 8:
        return packed[..., :d_out]
    cpb = 8 // cbits
    mask = (1 << cbits) - 1
    parts = [(packed >> (cbits * j)) & mask for j in range(cpb)]
    codes = jnp.stack(parts, axis=-1)  # (..., nbytes, cpb): little-endian fields
    return codes.reshape(*packed.shape[:-1], packed.shape[-1] * cpb)[..., :d_out]


def dequant_block(
    codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray, group_size: int
) -> jnp.ndarray:
    """Group-wise dequantize ``(d_in, t)`` codes with ``(G, t)`` metadata."""
    d_in, t = codes.shape
    g = d_in // group_size
    grouped = codes.astype(jnp.float32).reshape(g, group_size, t)
    deq = (grouped - zero[:, None, :]) * scale[:, None, :]
    return deq.reshape(d_in, t)


def _qmm_kernel(x_ref, w_ref, s_ref, z_ref, o_ref, *, cbits, group_size, tile):
    x = x_ref[...]  # (B, d_in) — resident across all grid steps
    codes = unpack_container(w_ref[...], cbits, tile)  # (d_in, tile)
    deq = dequant_block(codes, s_ref[...], z_ref[...], group_size)
    o_ref[...] = jnp.dot(x, deq, preferred_element_type=jnp.float32)


def quant_matmul(
    x: jnp.ndarray,
    packed: jnp.ndarray,
    scale: jnp.ndarray,
    zero: jnp.ndarray,
    *,
    cbits: int,
    group_size: int,
    d_out: int,
    tile: int | None = None,
) -> jnp.ndarray:
    """``y = x @ dequant(packed)``.

    Parameters
    ----------
    x:  (B, d_in) float32 activations.
    packed: (d_in, d_out * cbits / 8) uint8 container-packed codes.
    scale/zero: (d_in // group_size, d_out) float32.
    cbits: container bit-width (2, 4 or 8; 3-bit codes use cbits=4).
    tile: output-tile width (defaults to min(d_out, 256); must divide d_out
          and be a multiple of 8/cbits so byte boundaries align).
    """
    b, d_in = x.shape
    cpb = 8 // cbits
    if tile is None:
        tile = min(d_out, 256)
    assert d_out % tile == 0 and tile % cpb == 0
    g = d_in // group_size

    kernel = functools.partial(
        _qmm_kernel, cbits=cbits, group_size=group_size, tile=tile
    )
    return pl.pallas_call(
        kernel,
        grid=(d_out // tile,),
        in_specs=[
            pl.BlockSpec((b, d_in), lambda i: (0, 0)),  # x stays resident
            pl.BlockSpec((d_in, tile // cpb), lambda i: (0, i)),
            pl.BlockSpec((g, tile), lambda i: (0, i)),
            pl.BlockSpec((g, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, d_out), jnp.float32),
        interpret=True,
    )(x, packed, scale, zero)

"""Pure-jnp correctness oracles for every L1 kernel.

These are deliberately *independent* implementations: straight-line jnp with
no pallas, no shared tiling code, numpy-style unpacking written from the
codec spec rather than imported from the kernels.  pytest pins each kernel
to its oracle across a hypothesis sweep of shapes/bit-widths
(python/tests/test_kernels.py), and the rust integration tests pin the
PJRT-executed artifacts to numbers produced through these functions.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_unpack(packed: jnp.ndarray, cbits: int, n: int) -> jnp.ndarray:
    """Unpack little-endian ``cbits``-bit fields along the last axis."""
    if cbits == 8:
        return packed[..., :n]
    cpb = 8 // cbits
    mask = (1 << cbits) - 1
    cols = []
    for byte_idx in range(packed.shape[-1]):
        byte = packed[..., byte_idx]
        for j in range(cpb):
            cols.append((byte >> (cbits * j)) & mask)
    codes = jnp.stack(cols, axis=-1)
    return codes[..., :n]


def ref_dequant(codes, scale, zero, group_size: int) -> jnp.ndarray:
    """Group-wise dequantize codes (d_in, d_out) with (G, d_out) metadata."""
    d_in, d_out = codes.shape
    g = d_in // group_size
    out = codes.astype(jnp.float32).reshape(g, group_size, d_out)
    out = (out - zero[:, None, :]) * scale[:, None, :]
    return out.reshape(d_in, d_out)


def ref_quant_matmul(x, packed, scale, zero, *, cbits, group_size, d_out):
    w = ref_dequant(ref_unpack(packed, cbits, d_out), scale, zero, group_size)
    return x @ w


def ref_lowrank_delta(
    x, u_packed, u_scale, u_zero, v_packed, v_scale, v_zero,
    *, rank, d_out, cbits=4, u_group=None, v_group=None,
):
    d_in = x.shape[1]
    u_group = u_group or d_in // u_scale.shape[0]
    v_group = v_group or rank // v_scale.shape[0]
    u = ref_dequant(ref_unpack(u_packed, cbits, rank), u_scale, u_zero, u_group)
    v = ref_dequant(ref_unpack(v_packed, cbits, d_out), v_scale, v_zero, v_group)
    return (x @ u) @ v


def ref_expert_fp16(x, w1, w2, w3):
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def ref_expert_quant(
    x,
    w1_packed, w1_scale, w1_zero,
    w2_packed, w2_scale, w2_zero,
    w3_packed, w3_scale, w3_zero,
    *, cbits, group_size, d_ff, d_out,
):
    d = x.shape[1]
    w1 = ref_dequant(ref_unpack(w1_packed, cbits, d_ff), w1_scale, w1_zero, group_size)
    w3 = ref_dequant(ref_unpack(w3_packed, cbits, d_ff), w3_scale, w3_zero, group_size)
    w2 = ref_dequant(ref_unpack(w2_packed, cbits, d_out), w2_scale, w2_zero, group_size)
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


def ref_expert_quant_comp(
    x, w1, w2, w3, c1, c2, c3, *, cbits, group_size, d_ff, d_out, rank, v_group=4
):
    """Oracle for the compensated expert: reconstruct Ŵi = deq(Wi) + Ui·Vi
    explicitly in weight space, then run the plain SwiGLU."""
    d = x.shape[1]

    def mat(w, n_out, g):
        packed, scale, zero = w
        return ref_dequant(ref_unpack(packed, cbits, n_out), scale, zero, g)

    def factor_pair(c, d_in_f, n_out):
        # Factors are always INT3 codes in 4-bit containers, independent of
        # the weight container width.
        up, us, uz, vp, vs, vz = c
        u = ref_dequant(ref_unpack(up, 4, rank), us, uz, d_in_f // us.shape[0])
        v = ref_dequant(ref_unpack(vp, 4, n_out), vs, vz, rank // vs.shape[0])
        return u @ v

    w1m = mat(w1, d_ff, group_size) + factor_pair(c1, d, d_ff)
    w3m = mat(w3, d_ff, group_size) + factor_pair(c3, d, d_ff)
    w2m = mat(w2, d_out, group_size) + factor_pair(c2, d_ff, d_out)
    return (jax.nn.silu(x @ w1m) * (x @ w3m)) @ w2m


def ref_decode_attention(q, k_cache, v_cache, lengths):
    b, h, dh = q.shape
    s = k_cache.shape[2]
    scores = jnp.einsum("bhd,bhsd->bhs", q, k_cache) / (dh**0.5)
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, v_cache)

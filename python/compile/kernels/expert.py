"""Fused SwiGLU MoE expert kernels (fp16 reference and quantized hot path).

An expert is the Mixtral FFN:  ``y = (silu(x·W1) ⊙ (x·W3)) · W2`` with
``W1, W3 ∈ (d, f)`` and ``W2 ∈ (f, d)``.

Structure (two pallas calls, DESIGN.md §Hardware-Adaptation):

* **up kernel** — grid over ``f`` tiles.  Each step stages the matching W1
  and W3 tiles (packed, for the quant variant), dequants both in VMEM, and
  writes ``h_tile = silu(x·W1_t) ⊙ (x·W3_t)``.  Fusing gate and up halves
  the activation traffic vs two separate matmuls — the moral equivalent of
  the paper's fused dequant-GEMM CUDA kernel.
* **down kernel** — ``h·W2``, which is exactly `quant_matmul` (or a plain
  tiled matmul for fp16); reused rather than re-implemented.

The low-rank compensation delta is *not* fused here: it is a separate
`lowrank_delta` call so that L3 can decide per token whether to apply it
(that decision is the paper's contribution and lives in rust).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant_matmul import quant_matmul, unpack_container, dequant_block


def _up_fp16_kernel(x_ref, w1_ref, w3_ref, h_ref):
    x = x_ref[...]
    gate = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    up = jnp.dot(x, w3_ref[...], preferred_element_type=jnp.float32)
    h_ref[...] = jax.nn.silu(gate) * up


def _down_fp16_kernel(h_ref, w2_ref, o_ref):
    o_ref[...] = jnp.dot(h_ref[...], w2_ref[...], preferred_element_type=jnp.float32)


def expert_fp16(
    x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, w3: jnp.ndarray,
    *, tile: int | None = None,
) -> jnp.ndarray:
    """Full-precision SwiGLU expert (baseline path and training parity check)."""
    b, d = x.shape
    f = w1.shape[1]
    t = tile or min(f, 256)
    assert f % t == 0

    h = pl.pallas_call(
        _up_fp16_kernel,
        grid=(f // t,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((d, t), lambda i: (0, i)),
            pl.BlockSpec((d, t), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, t), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=True,
    )(x, w1, w3)

    td = min(d, 256)
    return pl.pallas_call(
        _down_fp16_kernel,
        grid=(d // td,),
        in_specs=[
            pl.BlockSpec((b, f), lambda i: (0, 0)),
            pl.BlockSpec((f, td), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, td), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(h, w2)


def _up_quant_kernel(
    x_ref, w1_ref, s1_ref, z1_ref, w3_ref, s3_ref, z3_ref, h_ref,
    *, cbits, group_size, tile,
):
    x = x_ref[...]
    w1 = dequant_block(
        unpack_container(w1_ref[...], cbits, tile), s1_ref[...], z1_ref[...], group_size
    )
    w3 = dequant_block(
        unpack_container(w3_ref[...], cbits, tile), s3_ref[...], z3_ref[...], group_size
    )
    gate = jnp.dot(x, w1, preferred_element_type=jnp.float32)
    up = jnp.dot(x, w3, preferred_element_type=jnp.float32)
    h_ref[...] = jax.nn.silu(gate) * up


def expert_quant(
    x: jnp.ndarray,
    w1_packed, w1_scale, w1_zero,
    w2_packed, w2_scale, w2_zero,
    w3_packed, w3_scale, w3_zero,
    *,
    cbits: int,
    group_size: int,
    d_ff: int,
    d_out: int,
    tile: int | None = None,
) -> jnp.ndarray:
    """Quantized SwiGLU expert: fused dequant gate/up, then dequant down-proj.

    This is the kernel every *non-compensated* expert executes (on GPU or on
    the NDP device); compensated experts add a `lowrank_delta` on top.
    """
    b, d = x.shape
    cpb = 8 // cbits
    t = tile or min(d_ff, 256)
    assert d_ff % t == 0 and t % cpb == 0
    g = d // group_size

    kernel = functools.partial(
        _up_quant_kernel, cbits=cbits, group_size=group_size, tile=t
    )
    h = pl.pallas_call(
        kernel,
        grid=(d_ff // t,),
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((d, t // cpb), lambda i: (0, i)),
            pl.BlockSpec((g, t), lambda i: (0, i)),
            pl.BlockSpec((g, t), lambda i: (0, i)),
            pl.BlockSpec((d, t // cpb), lambda i: (0, i)),
            pl.BlockSpec((g, t), lambda i: (0, i)),
            pl.BlockSpec((g, t), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, t), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, d_ff), jnp.float32),
        interpret=True,
    )(x, w1_packed, w1_scale, w1_zero, w3_packed, w3_scale, w3_zero)

    return quant_matmul(
        h, w2_packed, w2_scale, w2_zero,
        cbits=cbits, group_size=group_size, d_out=d_out,
    )


FACTOR_CBITS = 4  # compensator factors: INT3 codes in 4-bit containers


def _up_quant_comp_kernel(
    x_ref,
    w1_ref, s1_ref, z1_ref, u1p_ref, u1s_ref, u1z_ref, v1p_ref, v1s_ref, v1z_ref,
    w3_ref, s3_ref, z3_ref, u3p_ref, u3s_ref, u3z_ref, v3p_ref, v3s_ref, v3z_ref,
    h_ref,
    *, cbits, group_size, tile, rank, u_group, v_group,
):
    """Fused gate/up with low-rank restoration on the pre-activations.

    Per output tile t:  ``g_t = x·W1_t + (x·U1)·V1_t`` (same for up/W3).
    ``U`` (d, r) stays VMEM-resident across the grid; only the ``V`` tile
    moves.  The correction is applied *before* the SiLU nonlinearity — the
    activation-space shortcut is only valid for linear maps, so compensation
    of W1/W3 must happen here rather than on the expert output (DESIGN.md §7).
    """
    x = x_ref[...]

    def corrected(w_ref, s_ref, z_ref, up_ref, us_ref, uz_ref, vp_ref, vs_ref, vz_ref):
        w = dequant_block(
            unpack_container(w_ref[...], cbits, tile), s_ref[...], z_ref[...], group_size
        )
        u = dequant_block(
            unpack_container(up_ref[...], FACTOR_CBITS, rank),
            us_ref[...], uz_ref[...], u_group,
        )
        v = dequant_block(
            unpack_container(vp_ref[...], FACTOR_CBITS, tile),
            vs_ref[...], vz_ref[...], v_group,
        )
        base = jnp.dot(x, w, preferred_element_type=jnp.float32)
        xu = jnp.dot(x, u, preferred_element_type=jnp.float32)
        return base + jnp.dot(xu, v, preferred_element_type=jnp.float32)

    gate = corrected(w1_ref, s1_ref, z1_ref, u1p_ref, u1s_ref, u1z_ref, v1p_ref, v1s_ref, v1z_ref)
    up = corrected(w3_ref, s3_ref, z3_ref, u3p_ref, u3s_ref, u3z_ref, v3p_ref, v3s_ref, v3z_ref)
    h_ref[...] = jax.nn.silu(gate) * up


def expert_quant_comp(
    x: jnp.ndarray,
    w1, w2, w3,  # each: (packed, scale, zero) tuples
    c1, c2, c3,  # each: (u_packed, u_scale, u_zero, v_packed, v_scale, v_zero)
    *,
    cbits: int,
    group_size: int,
    d_ff: int,
    d_out: int,
    rank: int,
    v_group: int = 4,
    tile: int | None = None,
) -> jnp.ndarray:
    """Compensated quantized expert:  ``Ŵi = Q⁻¹(Q(Wi)) + Ui·Vi`` for i∈{1,2,3}.

    This is the executable the *top-n* experts run after their compensators
    are fetched (paper §3.2).  ``rank`` is the padded executable rank
    (`compensate.build_compensator(pad_to=...)`); true per-matrix ranks are
    smaller and the padding columns are exact zeros.

    The w2 (down-proj) correction uses the activation-space form
    ``h·Ŵ2 = quant_matmul(h) + lowrank_delta(h)`` — reusing the two tested
    kernels instead of a third fused variant.
    """
    from .lowrank import lowrank_delta

    b, d = x.shape
    cpb = 8 // cbits
    t = tile or min(d_ff, 256)
    assert d_ff % t == 0 and t % cpb == 0
    g = d // group_size
    u_group = min(group_size, d)
    gu = d // u_group
    gv = rank // v_group

    kernel = functools.partial(
        _up_quant_comp_kernel,
        cbits=cbits, group_size=group_size, tile=t,
        rank=rank, u_group=u_group, v_group=v_group,
    )
    fcpb = 8 // FACTOR_CBITS
    rpb = rank // fcpb  # packed bytes per U row (4-bit factor container)

    def proj_specs():
        return [
            pl.BlockSpec((d, t // cpb), lambda i: (0, i)),   # W packed tile
            pl.BlockSpec((g, t), lambda i: (0, i)),          # scale
            pl.BlockSpec((g, t), lambda i: (0, i)),          # zero
            pl.BlockSpec((d, rpb), lambda i: (0, 0)),        # U packed (resident)
            pl.BlockSpec((gu, rank), lambda i: (0, 0)),
            pl.BlockSpec((gu, rank), lambda i: (0, 0)),
            pl.BlockSpec((rank, t // fcpb), lambda i: (0, i)),  # V packed tile
            pl.BlockSpec((gv, t), lambda i: (0, i)),
            pl.BlockSpec((gv, t), lambda i: (0, i)),
        ]

    h = pl.pallas_call(
        kernel,
        grid=(d_ff // t,),
        in_specs=[pl.BlockSpec((b, d), lambda i: (0, 0))] + proj_specs() + proj_specs(),
        out_specs=pl.BlockSpec((b, t), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, d_ff), jnp.float32),
        interpret=True,
    )(x, *w1, *c1, *w3, *c3)

    y = quant_matmul(
        h, *w2, cbits=cbits, group_size=group_size, d_out=d_out
    )
    return y + lowrank_delta(
        h, *c2, rank=rank, d_out=d_out, cbits=FACTOR_CBITS,
        u_group=min(group_size, d_ff), v_group=v_group,
    )

"""GPTQ — Hessian-guided post-training quantization (accuracy baseline).

Reference: Frantar et al., "GPTQ: Accurate Post-Training Quantization for
Generative Pre-trained Transformers" (2022).  The paper's Fig. 6 compares
against GPTQ at INT2/INT3; we implement the standard algorithm:

Given calibration activations ``X`` (n_samples, d_in) feeding ``y = x @ W``,
minimize ``‖XW − X·Ŵ‖²`` column-block by column-block.  With
``H = 2 XᵀX + λI`` and its Cholesky-based inverse, each weight column (here:
*row*, since our layout is ``(d_in, d_out)`` contracting over d_in) is
quantized in order and the residual error is propagated into not-yet-
quantized rows via ``H⁻¹``.

Implementation follows the reference pseudo-code with per-group (scale, zero)
computed lazily when the sweep enters a new group, blocked updates for
cache-friendliness, and the usual 1% dampening.
"""

from __future__ import annotations

import numpy as np

from .uniform import QuantParams


def _cholesky_inv_upper(H: np.ndarray) -> np.ndarray:
    """Upper Cholesky factor of H⁻¹, as used by the GPTQ recurrences."""
    Hinv = np.linalg.inv(H)
    # Cholesky of the inverse, upper-triangular form.
    return np.linalg.cholesky(Hinv).T


def quantize_gptq(
    W: np.ndarray,
    X: np.ndarray,
    bits: int,
    group_size: int = 64,
    block_size: int = 64,
    percdamp: float = 0.01,
) -> QuantParams:
    """GPTQ-quantize ``W`` (d_in, d_out) given calibration activations ``X``.

    ``X`` has shape (n_samples, d_in); rows of ``W`` are quantized in index
    order with error feedback through the inverse Hessian.
    """
    W = np.asarray(W, dtype=np.float64)  # accumulate in f64 for stability
    X = np.asarray(X, dtype=np.float64)
    d_in, d_out = W.shape
    if d_in % group_size != 0:
        raise ValueError(f"d_in={d_in} not divisible by group_size={group_size}")
    n_groups = d_in // group_size
    qmax = float(2**bits - 1)

    H = 2.0 * (X.T @ X)
    # Dead inputs (zero variance) get unit diagonal so H stays invertible.
    dead = np.diag(H) == 0.0
    H[dead, dead] = 1.0
    W[dead, :] = 0.0
    damp = percdamp * float(np.mean(np.diag(H)))
    H[np.arange(d_in), np.arange(d_in)] += damp
    Hinv_chol = _cholesky_inv_upper(H)

    Wq = W.copy()  # progressively overwritten with dequantized values
    codes = np.zeros((d_in, d_out), dtype=np.uint8)
    scale = np.zeros((n_groups, d_out), dtype=np.float32)
    zero = np.zeros((n_groups, d_out), dtype=np.float32)

    for b0 in range(0, d_in, block_size):
        b1 = min(b0 + block_size, d_in)
        Wb = Wq[b0:b1, :].copy()
        Eb = np.zeros_like(Wb)
        Hb = Hinv_chol[b0:b1, b0:b1]

        for i in range(b1 - b0):
            row = b0 + i
            g = row // group_size
            if row % group_size == 0:
                # (Re-)fit scale/zero on the *current* (error-compensated)
                # values of this group, like the reference implementation.
                seg = Wq[row : row + group_size, :]
                wmin, wmax = seg.min(axis=0), seg.max(axis=0)
                s = (wmax - wmin) / qmax
                s = np.where(s <= 1e-12, 1.0, s)
                scale[g] = s.astype(np.float32)
                zero[g] = (-wmin / s).astype(np.float32)

            d = Hb[i, i]
            w = Wb[i, :]
            c = np.clip(np.rint(w / scale[g] + zero[g]), 0.0, qmax)
            codes[row, :] = c.astype(np.uint8)
            dq = (c - zero[g]) * scale[g]
            err = (w - dq) / d
            # Propagate into the not-yet-quantized rows of this block.
            if i + 1 < b1 - b0:
                Wb[i + 1 :, :] -= np.outer(Hb[i, i + 1 :], err)
            Eb[i, :] = err
            Wb[i, :] = dq

        Wq[b0:b1, :] = Wb
        # Propagate the block's accumulated error into all later blocks.
        if b1 < d_in:
            Wq[b1:, :] -= Hinv_chol[b0:b1, b1:].T @ Eb

    return QuantParams(
        codes=codes,
        scale=scale,
        zero=zero,
        bits=bits,
        group_size=group_size,
    )

"""Bit-packing codecs for low-bit weight codes.

Two distinct concerns, kept separate on purpose (DESIGN.md §7):

* **Storage / bandwidth accounting** — what actually crosses PCIe / the NDP
  link.  2-, 4- and 8-bit pack exactly (4, 2, 1 codes per byte).  3-bit uses
  the classic 8-codes -> 3-bytes codec, so every bit-width here is *true*
  packed size; ``packed_nbytes`` is what the rust transfer simulator charges.

* **Kernel container** — what the pallas kernel unpacks in VMEM.  The kernel
  consumes 4-bit containers for 3-bit codes (byte-aligned shifts only); the
  repack is a build-time transform (`to_container`).  2/4/8-bit kernels
  consume the storage format directly.

All functions operate on the flattened last axis; arrays must have a
multiple-of-``codes_per_chunk`` number of elements along it (weight shapes in
BEAM are powers of two, so this always holds).
"""

from __future__ import annotations

import numpy as np

#: codes per packed chunk / bytes per packed chunk, per bit-width
_CHUNK = {2: (4, 1), 3: (8, 3), 4: (2, 1), 8: (1, 1)}


def container_bits(bits: int) -> int:
    """Bit-width of the kernel-side container (3-bit rides in 4-bit)."""
    return 4 if bits == 3 else bits


def packed_nbytes(n_codes: int, bits: int) -> int:
    """True packed byte count for ``n_codes`` codes at ``bits`` bits."""
    cpc, bpc = _CHUNK[bits]
    if n_codes % cpc != 0:
        raise ValueError(f"{n_codes} codes not a multiple of chunk {cpc} for {bits}-bit")
    return n_codes // cpc * bpc


def pack_codes(codes: np.ndarray, bits: int) -> np.ndarray:
    """Pack uint8 codes (< 2^bits) into a uint8 byte stream along the last axis."""
    codes = np.ascontiguousarray(codes, dtype=np.uint8)
    if codes.size and int(codes.max()) >= (1 << bits):
        raise ValueError(f"code out of range for {bits}-bit")
    *lead, n = codes.shape
    flat = codes.reshape(-1, n)

    if bits == 8:
        packed = flat
    elif bits == 4:
        pairs = flat.reshape(flat.shape[0], n // 2, 2)
        packed = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(np.uint8)
    elif bits == 2:
        quads = flat.reshape(flat.shape[0], n // 4, 4)
        packed = (
            quads[..., 0]
            | (quads[..., 1] << 2)
            | (quads[..., 2] << 4)
            | (quads[..., 3] << 6)
        ).astype(np.uint8)
    elif bits == 3:
        if n % 8 != 0:
            raise ValueError(f"3-bit packing needs multiple-of-8 axis, got {n}")
        oct_ = flat.reshape(flat.shape[0], n // 8, 8).astype(np.uint32)
        # 8 codes -> one 24-bit word, little-endian 3-bit fields.
        word = np.zeros(oct_.shape[:2], dtype=np.uint32)
        for j in range(8):
            word |= oct_[..., j] << (3 * j)
        packed = np.stack(
            [(word & 0xFF), (word >> 8) & 0xFF, (word >> 16) & 0xFF], axis=-1
        ).astype(np.uint8)
        packed = packed.reshape(packed.shape[0], -1)
    else:
        raise ValueError(f"unsupported bit-width {bits}")

    return packed.reshape(*lead, -1)


def unpack_codes(packed: np.ndarray, bits: int, n_codes: int) -> np.ndarray:
    """Inverse of :func:`pack_codes`; ``n_codes`` is the unpacked last-axis length."""
    packed = np.ascontiguousarray(packed, dtype=np.uint8)
    *lead, nb = packed.shape
    flat = packed.reshape(-1, nb)

    if bits == 8:
        out = flat
    elif bits == 4:
        out = np.empty((flat.shape[0], nb * 2), dtype=np.uint8)
        out[:, 0::2] = flat & 0x0F
        out[:, 1::2] = flat >> 4
    elif bits == 2:
        out = np.empty((flat.shape[0], nb * 4), dtype=np.uint8)
        for j in range(4):
            out[:, j::4] = (flat >> (2 * j)) & 0x03
    elif bits == 3:
        trip = flat.reshape(flat.shape[0], nb // 3, 3).astype(np.uint32)
        word = trip[..., 0] | (trip[..., 1] << 8) | (trip[..., 2] << 16)
        out = np.empty((flat.shape[0], nb // 3, 8), dtype=np.uint8)
        for j in range(8):
            out[..., j] = ((word >> (3 * j)) & 0x07).astype(np.uint8)
        out = out.reshape(flat.shape[0], -1)
    else:
        raise ValueError(f"unsupported bit-width {bits}")

    out = out[:, :n_codes]
    return out.reshape(*lead, n_codes)


def to_container(codes: np.ndarray, bits: int) -> np.ndarray:
    """Pack codes into the *kernel container* format (see module docstring).

    Returns a uint8 array packed at ``container_bits(bits)`` — identical to
    :func:`pack_codes` output except for 3-bit, which is widened to the 4-bit
    container the pallas kernel unpacks with byte-aligned shifts.
    """
    return pack_codes(codes, container_bits(bits))

"""Group-wise asymmetric uniform quantization.

Weight layout convention used across BEAM: ``W`` has shape ``(d_in, d_out)``
and the forward pass computes ``y = x @ W``.  Quantization groups run along
the *contraction* axis (``d_in``): each contiguous group of ``group_size``
input rows shares one ``(scale, zero)`` pair per output column, i.e.

    codes[g*G + i, o] = clip(round(W[g*G + i, o] / scale[g, o] + zero[g, o]))
    deq  [g*G + i, o] = (codes[...] - zero[g, o]) * scale[g, o]

This is the format the L1 pallas kernel (`kernels/quant_matmul.py`) consumes
and the rust reference dequantizer (`rust/src/quant/dequant.rs`) mirrors —
the three implementations are pinned to each other by tests.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class QuantParams:
    """A quantized weight matrix plus the metadata needed to dequantize it.

    Attributes
    ----------
    codes:       uint8 ``(d_in, d_out)`` — unpacked integer codes in
                 ``[0, 2^bits - 1]`` (packing is a separate, lossless step).
    scale:       float32 ``(d_in // group_size, d_out)``.
    zero:        float32 ``(d_in // group_size, d_out)`` — *float* zero-point
                 (HQQ optimizes it continuously; uniform RTN rounds it).
    bits:        bit-width of the codes (2..8).
    group_size:  rows per quantization group along ``d_in``.
    """

    codes: np.ndarray
    scale: np.ndarray
    zero: np.ndarray
    bits: int
    group_size: int

    @property
    def shape(self) -> tuple[int, int]:
        return self.codes.shape

    def dequantize(self) -> np.ndarray:
        return dequantize(self)

    def ideal_nbits(self) -> int:
        """Total payload size in *bits* under ideal packing (codes only)."""
        return self.codes.size * self.bits

    def metadata_nbytes(self) -> int:
        """scale+zero payload (fp16 on the wire, like HQQ's meta tensors)."""
        return (self.scale.size + self.zero.size) * 2


def _group(W: np.ndarray, group_size: int) -> np.ndarray:
    d_in, d_out = W.shape
    if d_in % group_size != 0:
        raise ValueError(f"d_in={d_in} not divisible by group_size={group_size}")
    return W.reshape(d_in // group_size, group_size, d_out)


def quantize_uniform(W: np.ndarray, bits: int, group_size: int = 64) -> QuantParams:
    """Round-to-nearest asymmetric quantization (the non-optimized baseline)."""
    if not 2 <= bits <= 8:
        raise ValueError(f"bits must be in [2, 8], got {bits}")
    W = np.asarray(W, dtype=np.float32)
    grouped = _group(W, group_size)
    qmax = float(2**bits - 1)
    wmin = grouped.min(axis=1)
    wmax = grouped.max(axis=1)
    scale = (wmax - wmin) / qmax
    # Degenerate all-equal groups: keep scale positive so dequant is exact.
    scale = np.where(scale <= 1e-12, 1.0, scale).astype(np.float32)
    zero = (-wmin / scale).astype(np.float32)
    codes = quantize_with_params(W, scale, zero, bits, group_size)
    return QuantParams(codes=codes, scale=scale, zero=zero, bits=bits, group_size=group_size)


def quantize_with_params(
    W: np.ndarray, scale: np.ndarray, zero: np.ndarray, bits: int, group_size: int
) -> np.ndarray:
    """Quantize ``W`` to codes given fixed (scale, zero)."""
    grouped = _group(np.asarray(W, dtype=np.float32), group_size)
    qmax = float(2**bits - 1)
    codes = np.rint(grouped / scale[:, None, :] + zero[:, None, :])
    codes = np.clip(codes, 0.0, qmax).astype(np.uint8)
    return codes.reshape(W.shape)


def dequantize(q: QuantParams) -> np.ndarray:
    """Inverse map Q⁻¹: codes -> float32 weights."""
    grouped = _group(q.codes.astype(np.float32), q.group_size)
    deq = (grouped - q.zero[:, None, :]) * q.scale[:, None, :]
    return deq.reshape(q.codes.shape).astype(np.float32)


def relative_residual_fro(W: np.ndarray, q: QuantParams) -> float:
    """‖W − Q⁻¹(Q(W))‖_F / ‖W‖_F — the error metric of paper Fig. 4."""
    W = np.asarray(W, dtype=np.float32)
    num = float(np.linalg.norm(W - q.dequantize()))
    den = float(np.linalg.norm(W)) or 1.0
    return num / den

"""Half-Quadratic Quantization (HQQ) — calibration-free zero-point optimization.

Reference: Badri & Shaji, "Half-Quadratic Quantization of Large Machine
Learning Models" (2023).  The paper's method (§3.1 step 2) performs "low-bit
quantization with HQQ-style weight optimization" before taking the residual
SVD; we implement the same procedure.

HQQ keeps the RTN scale but optimizes the (continuous) zero-point ``z`` to
minimize ``‖W − (Q(W) − z)·s‖_p^p`` with ``p < 1`` via half-quadratic
splitting.  Introducing the auxiliary residual ``e``:

    min_{z, e}  ‖e‖_p^p + (β/2)‖W − (deq(z)) − e‖²

alternates two closed-form steps:

  1. *shrink*: ``e ← generalized_soft_threshold_p(W − deq, β)``
  2. *zero update*: ``z ← mean_group(Q − (W − e)/s)``

with ``β`` annealed upward by ``kappa`` each iteration.  ~20 iterations
suffice; the whole thing is vectorized numpy and runs offline only.
"""

from __future__ import annotations

import numpy as np

from .uniform import QuantParams, quantize_uniform, quantize_with_params, _group


def _shrink_lp(x: np.ndarray, beta: float, p: float) -> np.ndarray:
    """Generalized soft-thresholding prox for the lp-norm (p < 1).

    prox_{‖·‖_p^p / β}(x) ≈ sign(x) · relu(|x| − |x|^{p−1} · p / β)

    (the standard first-order approximation used by HQQ).
    """
    ax = np.abs(x)
    # |x|^{p-1} explodes at 0; the relu clamps those entries to 0 anyway.
    with np.errstate(divide="ignore"):
        thresh = np.where(ax > 1e-8, ax ** (p - 1.0), 0.0) * (p / beta)
    return np.sign(x) * np.maximum(ax - thresh, 0.0)


def quantize_hqq(
    W: np.ndarray,
    bits: int,
    group_size: int = 64,
    iters: int = 20,
    p: float = 0.7,
    beta: float = 10.0,
    kappa: float = 1.01,
) -> QuantParams:
    """HQQ quantization of ``W`` (layout ``(d_in, d_out)``, see uniform.py).

    Returns a :class:`QuantParams` whose ``zero`` has been optimized; ``scale``
    is the RTN scale (HQQ holds scale fixed — optimizing both is unstable at
    sub-4-bit, per the HQQ blog post).
    """
    W = np.asarray(W, dtype=np.float32)
    base = quantize_uniform(W, bits, group_size)
    scale, zero = base.scale.copy(), base.zero.copy()
    Wg = _group(W, group_size)

    for _ in range(iters):
        codes = quantize_with_params(W, scale, zero, bits, group_size)
        Cg = _group(codes.astype(np.float32), group_size)
        deq = (Cg - zero[:, None, :]) * scale[:, None, :]
        e = _shrink_lp(Wg - deq, beta, p)
        # Closed-form zero update given codes and the shrunk residual.
        zero = np.mean(Cg - (Wg - e) / scale[:, None, :], axis=1).astype(np.float32)
        beta *= kappa

    codes = quantize_with_params(W, scale, zero, bits, group_size)
    return QuantParams(codes=codes, scale=scale, zero=zero, bits=bits, group_size=group_size)

"""Offline quantization toolbox for BEAM.

Everything in this package runs at *artifact build time* only (``make
artifacts``); nothing here is imported on the rust request path.

Modules
-------
uniform   group-wise asymmetric round-to-nearest quantization (any bit-width)
hqq       half-quadratic zero-point optimization (calibration-free), the
          quantizer BEAM ships with (paper §3.1 step 2)
gptq      Hessian-guided per-column quantization (accuracy baseline, paper §4.1)
packing   bit-packing codecs (2/4/8-bit true packing, 3-bit 8->3-byte codec)
"""

from .uniform import QuantParams, quantize_uniform, dequantize, quantize_with_params
from .hqq import quantize_hqq
from .gptq import quantize_gptq
from .packing import pack_codes, unpack_codes, packed_nbytes, container_bits

__all__ = [
    "QuantParams",
    "quantize_uniform",
    "quantize_with_params",
    "dequantize",
    "quantize_hqq",
    "quantize_gptq",
    "pack_codes",
    "unpack_codes",
    "packed_nbytes",
    "container_bits",
]

//! Quickstart: load a BEAM model and serve two short requests.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Walks the whole public API surface in ~40 lines: manifest → engine →
//! staged model → serve engine with the paper's policy → report.

use std::sync::Arc;

use anyhow::Result;
use beam_moe::config::{PolicyConfig, PolicyKind, SystemConfig};
use beam_moe::coordinator::scheduler::serve;
use beam_moe::coordinator::ServeEngine;
use beam_moe::manifest::{Manifest, WeightStore};
use beam_moe::runtime::{Engine, StagedModel};
use beam_moe::workload::{WorkloadConfig, WorkloadGen};

fn main() -> Result<()> {
    // 1. Artifacts: HLO stages + weights, produced by `make artifacts`.
    let manifest = Manifest::load("artifacts/mixtral-tiny")?;
    println!(
        "model {}: {} layers × {} experts (top-{}), d={}",
        manifest.model.name,
        manifest.model.n_layers,
        manifest.model.n_experts,
        manifest.model.top_k,
        manifest.model.d_model
    );

    // 2. Runtime: PJRT CPU client + staged executables.
    let engine = Arc::new(Engine::cpu()?);
    let model = StagedModel::load(engine, manifest)?;

    // 3. Policy: the paper's router-guided compensation at 2-bit, top-1.
    let policy = PolicyConfig::new(PolicyKind::Beam, 2, 1);
    let sys = SystemConfig::scaled_for(&model.manifest.model, false);
    let mut serve_engine = ServeEngine::new(model, policy, sys)?;

    // 4. Two requests from the synthetic corpus, 24 tokens each.
    let eval = WeightStore::load(serve_engine.model.manifest.eval_path())?;
    let wl = WorkloadConfig::offline(2, 64, 24);
    let requests = WorkloadGen::generate(&wl, &eval)?;

    // 5. Serve and report.
    let report = serve(&mut serve_engine, requests)?;
    println!("{}", report.summary_line());
    println!(
        "generated {} tokens in {:.4} virtual s  ({:.1} tok/s on the simulated H100 testbed)",
        report.total_generated,
        report.virtual_seconds,
        report.tokens_per_second()
    );
    println!(
        "bytes moved: weights {} | compensators {} (the paper's extra traffic)",
        report.bytes.get("expert_weights").unwrap_or(&0),
        report.bytes.get("compensator").unwrap_or(&0),
    );
    Ok(())
}
